"""Real wall-clock generation/training overlap of the threaded runtime.

The virtual-clock figures (fig1/table1/fig4) PROVE the async scheduling
policy; this benchmark measures the async *transport*: the threaded
disaggregated runtime (DESIGN.md §Async runtime) against a forced-serial
baseline that drives the SAME engine/trainer/scheduler on one thread in
strict generate-then-train alternation (the colocated-synchronous
regime).

Both runs execute in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` — a fake
multi-device host — so the threaded runtime exercises the real
disaggregated submesh split (3 rollout / 1 trainer device) and weight
publication path.  Per mode we record, over a timed window that excludes
first-compile:

  * wall seconds and PPO versions completed,
  * effective throughput (tokens consumed by PPO updates / wall s),
  * trainer-busy fraction (wall time inside ``train_step``),
  * tokens generated *during* PPO updates — nonzero iff generation and
    training truly overlap (structurally zero for the serial baseline).

Results land in ``BENCH_async_overlap.json``; the paper-facing number is
the threaded / serial effective-throughput ratio (>=1.5x here, the same
direction as Table 1 at cluster scale).

Why a fixed 5-version window: the asynchrony advantage has two parts —
true wall-clock overlap, plus the eta-bounded *generate-ahead inventory*
(the rollout thread fills the staleness budget while the trainer is
busy, so the trainer never waits for data; the forced-serial baseline
cannot generate ahead by construction).  Both are the paper's mechanism
(Fig. 3).  The inventory part is bounded by eta * batch trajectories, so
on this container's 2 shared cores — where simultaneous decode and train
contend for the same silicon — very long windows converge toward the
contention-limited overlap-only ratio.  A short window right after
warmup measures the regime the paper actually runs in: trainer-bound
consumption against a standing staleness-window inventory.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import bench_path, emit

DEVICES = 4
STEPS = 5               # measured versions; fixed window (see module doc)
# 2 warm-up versions, not 1: the first weight pickup with ACTIVE slots
# compiles the full-width re-prefill signature (~1s on CPU); one version
# can complete before any slot is mid-flight at pickup, leaking that
# compile into the timed window for exactly one of the two modes.
WARMUP_STEPS = 2


def _build(seed: int = 0):
    """A tiny balanced pipeline: generation and training each take a
    comparable share, so overlap is visible in the throughput ratio."""
    import jax

    from repro.configs.base import ModelConfig, RLConfig
    from repro.core import (AsyncScheduler, EngineConfig, PPOTrainer,
                            RolloutEngine, ThreadedRuntime)
    from repro.data import tokenizer
    from repro.data.dataset import PromptStream
    from repro.launch.train import _place_disaggregated
    from repro.models.model import build_model

    cfg = ModelConfig(name="bench-overlap", family="dense", n_layers=2,
                      d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
                      vocab_size=tokenizer.VOCAB_SIZE)
    rl = RLConfig(batch_size=16, answers_per_prompt=4, max_staleness=4,
                  interruptible=True, ppo_minibatches=2,
                  microbatch_token_budget=128, lr=1e-3,
                  max_prompt_len=16, max_gen_len=16)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(seed))
    engine = RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=8, prompt_len=16, max_gen_len=16, seed=seed))
    trainer = PPOTrainer(model, rl, params)
    sched = AsyncScheduler(
        prompt_stream=PromptStream(seed=seed, answers_per_prompt=4,
                                   max_operand=9), rl=rl)
    roll_mesh = None
    n_roll = n_train = 1
    if len(jax.devices()) > 1:
        roll_mesh, train_mesh = _place_disaggregated(engine, trainer, 0.25)
        n_roll = roll_mesh.devices.size
        n_train = train_mesh.devices.size
    rt = ThreadedRuntime(engine=engine, trainer=trainer, scheduler=sched,
                         rollout_mesh=roll_mesh)
    return rt, n_roll, n_train


def _measure(mode: str, steps: int, seed: int = 0):
    import time

    rt, n_roll, n_train = _build(seed)
    if mode == "serial":
        drive = rt.run_serial
    else:
        def drive(n):
            return rt.run(n, timeout=600)   # a deadlock fails, not hangs
    drive(WARMUP_STEPS)                       # first-compiles outside the window
    v0 = rt.trainer.version
    busy0, tok_during0 = rt.trainer_busy_s, rt.tokens_during_train
    gen0, hist0 = rt.engine.tokens_generated, len(rt.history)
    t0 = time.perf_counter()
    drive(steps)
    wall = time.perf_counter() - t0
    consumed = sum(h.n_tokens for h in rt.history[hist0:])
    return {
        "mode": mode,
        "versions": rt.trainer.version - v0,
        "wall_s": round(wall, 3),
        "tokens_consumed": consumed,
        "effective_throughput_tok_s": round(consumed / wall, 2),
        "trainer_busy_fraction": round((rt.trainer_busy_s - busy0) / wall, 4),
        "tokens_generated": rt.engine.tokens_generated - gen0,
        "tokens_during_train": rt.tokens_during_train - tok_during0,
        "rollout_devices": n_roll, "trainer_devices": n_train,
    }


def _traced(trace_path: str) -> None:
    """Short traced re-run, SEPARATE from the timed windows above (the
    cost of tracing has its own benchmark, trace_overhead): writes the
    Chrome/Perfetto timeline that ``tools/trace_check.py`` validates
    and docs/OPERATIONS.md's walkthrough opens (DESIGN.md §Telemetry)."""
    from repro.obs import export, trace as tracing

    tracing.configure(enabled=True, actor="async_overlap")
    rt, _, _ = _build(seed=1)
    rt.run(WARMUP_STEPS + 1, timeout=600)
    tracing.configure(enabled=False)
    export.write_trace(trace_path)


def _child(steps: int, trace_path: str) -> None:
    import jax

    out = {"devices": len(jax.devices()), "steps": steps,
           "threaded": _measure("threaded", steps),
           "serial": _measure("serial", steps)}
    _traced(trace_path)
    print("BENCH_JSON=" + json.dumps(out), flush=True)


def main() -> None:
    steps = STEPS                             # >=5 PPO versions, smoke or full
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    # resolved in the parent: the child may not see run.py's SMOKE flag
    trace_path = os.path.abspath(bench_path("BENCH_async_overlap_trace.json"))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.async_overlap",
         "--child", str(steps), trace_path],
        capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("BENCH_JSON=")][-1]
    rec = json.loads(line[len("BENCH_JSON="):])

    thr = rec["threaded"]["effective_throughput_tok_s"]
    ser = rec["serial"]["effective_throughput_tok_s"]
    rec["throughput_ratio"] = round(thr / ser, 3) if ser else None
    rec["overlap_demonstrated"] = (
        rec["threaded"]["trainer_busy_fraction"] > 0
        and rec["threaded"]["tokens_during_train"] > 0)
    # gate the traced re-run: well-formed timeline with at least one
    # wall-clock-concurrent rollout/trainer span pair (the overlap the
    # timed ratio above measures, now visible in the artifact)
    from tools import trace_check
    tr = trace_check.load(trace_path)
    errors = trace_check.validate(tr)
    rec["trace"] = {
        "valid": not errors,
        "events": len(tr.get("traceEvents", [])),
        "concurrent_span_pairs": trace_check.concurrent_span_pairs(
            tr, "rollout", "trainer"),
        "errors": errors[:5],
    }
    with open(bench_path("BENCH_async_overlap.json"), "w") as f:
        json.dump(rec, f, indent=2)

    us_per_version = rec["threaded"]["wall_s"] / rec["threaded"]["versions"] * 1e6
    emit("async_overlap_threaded", us_per_version,
         f"throughput_x{rec['throughput_ratio']:.2f}")
    emit("async_overlap_busy_frac",
         rec["threaded"]["trainer_busy_fraction"] * 1e6,
         f"tok_during_train_{rec['threaded']['tokens_during_train']}")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]), sys.argv[3])
    else:
        main()
