"""Shared benchmark plumbing.

Every benchmark emits ``name,us_per_call,derived`` CSV rows via ``emit``:
us_per_call = wall microseconds per primitive call (controller step /
train step / packing call), derived = the paper-facing metric
(speedup x, accuracy, throughput tokens/s, ...).
"""
from __future__ import annotations

import os
import time
from contextlib import contextmanager

ROWS = []

# Smoke mode (CI): minimum-cost pass over the benchmark plumbing so the
# perf scripts can't silently rot.  Set by ``run.py --smoke`` (or the
# env var, for invoking a single module directly).
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def smoke_steps(n: int, smoke_n: int = 1) -> int:
    """``n`` normally, ``smoke_n`` when smoke mode is on."""
    return smoke_n if SMOKE else n


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0
