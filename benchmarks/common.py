"""Shared benchmark plumbing.

Every benchmark emits ``name,us_per_call,derived`` CSV rows via ``emit``:
us_per_call = wall microseconds per primitive call (controller step /
train step / packing call), derived = the paper-facing metric
(speedup x, accuracy, throughput tokens/s, ...).
"""
from __future__ import annotations

import glob
import os
import tempfile
import time
from contextlib import contextmanager

ROWS = []

# Smoke mode (CI): minimum-cost pass over the benchmark plumbing so the
# perf scripts can't silently rot.  Set by ``run.py --smoke`` (or the
# env var, for invoking a single module directly).
SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def smoke_steps(n: int, smoke_n: int = 1) -> int:
    """``n`` normally, ``smoke_n`` when smoke mode is on."""
    return smoke_n if SMOKE else n


def bench_path(filename: str) -> str:
    """Where a BENCH_*.json artifact goes.

    Committed baselines live in the repo root and are FULL-RUN numbers;
    smoke runs (CI, 2-core hosts) produce reduced-step numbers that must
    never clobber them, so with smoke mode on — or ``REPRO_BENCH_OUT``
    set — results land in the scratch dir instead.  The CI bench lane
    asserts ``git diff --exit-code`` afterwards and feeds the scratch dir
    to ``tools/check_bench.py`` (the benchmark-regression gate)."""
    out = os.environ.get("REPRO_BENCH_OUT", "")
    if not out and SMOKE:       # module-global read: sees run.py's rebinding
        out = os.path.join(tempfile.gettempdir(), "repro-bench")
    if not out:
        return filename
    os.makedirs(out, exist_ok=True)
    return os.path.join(out, filename)


def clean_bench_outputs() -> None:
    """Remove stale BENCH_*.json from the scratch out dir (no-op when
    results go to the repo root).  ``run.py`` calls this at the start of
    a smoke pass: the scratch dir is shared across runs, and a leftover
    artifact from a previous run must not satisfy the regression gate
    when the current run's benchmark crashes before writing."""
    d = os.path.dirname(bench_path("_"))
    if d:
        for f in glob.glob(os.path.join(d, "BENCH_*.json")):
            os.remove(f)


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0
