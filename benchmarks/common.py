"""Shared benchmark plumbing.

Every benchmark emits ``name,us_per_call,derived`` CSV rows via ``emit``:
us_per_call = wall microseconds per primitive call (controller step /
train step / packing call), derived = the paper-facing metric
(speedup x, accuracy, throughput tokens/s, ...).
"""
from __future__ import annotations

import time
from contextlib import contextmanager

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@contextmanager
def timed():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0
