"""Figure 1 analogue: execution-timeline utilization of the generation
pool, synchronous vs asynchronous.

The paper's Fig. 1 shows sync inference devices idling while (a) the
longest sequence in the batch finishes and (b) training runs.  We
measure generation-pool utilization = fraction of virtual time with
active decode slots, from the same simulator runs as Table 1.
"""
from __future__ import annotations

from benchmarks import common
from benchmarks.common import emit, timed
from repro.configs.base import RLConfig
from repro.core import AsyncRLController
from repro.core.simulator import (HardwareModel, SimEngine, SimPromptStream,
                                  SimTrainer, WorkloadModel, make_llm_timing)

STEPS = 5


class _UtilizationController(AsyncRLController):
    """Tracks busy (any active slot) vs idle generation time."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.busy = 0.0
        self.slot_time = 0.0          # slot-weighted utilization
        self._slots = kw["engine"].n_slots

    def run(self, n_steps, **kw):
        orig_step = self.engine.step
        orig_decode = self.timing.decode_step

        def step_wrapper():
            n = self.engine.n_active
            dt = orig_decode(n)
            self.busy += dt
            self.slot_time += dt * n / self._slots
            return orig_step()

        self.engine.step = step_wrapper
        return super().run(n_steps, **kw)


def _run(colocated):
    hw = HardwareModel()
    wl = WorkloadModel(n_params=7e9)
    devices = 128
    if colocated:
        timing = make_llm_timing(hw, wl, n_gen_devices=devices,
                                 n_train_devices=devices, colocated=True)
        rl = RLConfig(batch_size=256, max_staleness=0, interruptible=False)
    else:
        timing = make_llm_timing(hw, wl, n_gen_devices=96, n_train_devices=32)
        rl = RLConfig(batch_size=256, max_staleness=4, interruptible=True)
    eng = SimEngine(n_slots=1024, mean_len=6000, max_len=28_672,
                    prompt_len=1024, seed=0)
    ctl = _UtilizationController(engine=eng, trainer=SimTrainer(),
                                 prompt_stream=SimPromptStream(1024), rl=rl,
                                 timing=timing)
    ctl.run(common.smoke_steps(STEPS))
    total = max(ctl.clock, 1e-9)
    return ctl.busy / total, ctl.slot_time / total


def main():
    steps = common.smoke_steps(STEPS)
    with timed() as t:
        busy_s, slots_s = _run(colocated=True)
        busy_a, slots_a = _run(colocated=False)
    emit("fig1_gen_pool_utilization", 1e6 * t["s"] / (2 * steps),
         f"sync_busy={busy_s:.2f};sync_slot_util={slots_s:.2f};"
         f"areal_busy={busy_a:.2f};areal_slot_util={slots_a:.2f}")


if __name__ == "__main__":
    main()
