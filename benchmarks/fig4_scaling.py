"""Figure 4 analogue: strong scaling of effective training throughput
(tokens consumed by PPO per second) vs device count, AReaL vs the
synchronous baseline, for two context lengths.

Paper result: AReaL scales ~linearly; sync saturates (decode goes
memory-IO bound as per-GPU batch shrinks); up to 2.5x at 32k context.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs.base import RLConfig
from repro.core import AsyncRLController
from repro.core.simulator import (HardwareModel, SimEngine, SimPromptStream,
                                  SimTrainer, WorkloadModel, make_llm_timing)

STEPS = 6
BATCH = 512


def _throughput(n_params, devices, mean_len, max_len, *, colocated, seed=0):
    hw = HardwareModel()
    wl = WorkloadModel(n_params=n_params)
    if colocated:
        timing = make_llm_timing(hw, wl, n_gen_devices=devices,
                                 n_train_devices=devices, colocated=True)
        rl = RLConfig(batch_size=BATCH, max_staleness=0, interruptible=False)
    else:
        ng = int(devices * 0.75)
        timing = make_llm_timing(hw, wl, n_gen_devices=ng,
                                 n_train_devices=devices - ng)
        rl = RLConfig(batch_size=BATCH, max_staleness=8, interruptible=True)
    eng = SimEngine(n_slots=4 * BATCH, mean_len=mean_len, max_len=max_len,
                    prompt_len=1024, seed=seed)
    ctl = AsyncRLController(engine=eng, trainer=SimTrainer(),
                            prompt_stream=SimPromptStream(1024), rl=rl,
                            timing=timing)
    ctl.run(STEPS)
    return ctl.effective_throughput()


def main():
    for ctx_name, mean_len, max_len in [("16k", 4000, 15_360),
                                        ("32k", 8000, 31_744)]:
        for devices in (64, 128, 256, 512):
            with timed() as t:
                thr_s = _throughput(7e9, devices, mean_len, max_len,
                                    colocated=True)
                thr_a = _throughput(7e9, devices, mean_len, max_len,
                                    colocated=False)
            emit(f"fig4_7b_{ctx_name}_{devices}dev",
                 1e6 * t["s"] / (2 * STEPS),
                 f"sync={thr_s:.0f}tok/s;areal={thr_a:.0f}tok/s;"
                 f"ratio={thr_a / max(thr_s, 1e-9):.2f}x")


if __name__ == "__main__":
    main()
