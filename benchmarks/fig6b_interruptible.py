"""Figure 6b analogue: generation throughput with vs without
interruptible generation (without it, weight updates wait for the
longest in-flight response and admissions stall).

Paper result: +12% (1.5B) and +17% (7B) generation throughput on 4 nodes.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs.base import RLConfig
from repro.core import AsyncRLController
from repro.core.simulator import (HardwareModel, SimEngine, SimPromptStream,
                                  SimTrainer, WorkloadModel, make_llm_timing)

STEPS = 5


def _gen_throughput(n_params, interruptible, seed=0):
    hw = HardwareModel()
    wl = WorkloadModel(n_params=n_params)
    timing = make_llm_timing(hw, wl, n_gen_devices=24, n_train_devices=8)
    rl = RLConfig(batch_size=256, max_staleness=4,
                  interruptible=interruptible)
    eng = SimEngine(n_slots=1024, mean_len=6000, max_len=28_672,
                    prompt_len=1024, seed=seed)
    ctl = AsyncRLController(engine=eng, trainer=SimTrainer(),
                            prompt_stream=SimPromptStream(1024), rl=rl,
                            timing=timing)
    ctl.run(STEPS)
    return eng.tokens_generated / ctl.clock


def main():
    for name, n in [("1.5b", 1.5e9), ("7b", 7e9)]:
        with timed() as t:
            thr_on = _gen_throughput(n, True)
            thr_off = _gen_throughput(n, False)
        emit(f"fig6b_{name}", 1e6 * t["s"] / (2 * STEPS),
             f"interruptible={thr_on:.0f}tok/s;"
             f"without={thr_off:.0f}tok/s;gain={thr_on / thr_off - 1:+.1%}")


if __name__ == "__main__":
    main()
