"""Benchmark driver — one module per paper table/figure.

``python -m benchmarks.run [--smoke] [name]``: with ``--smoke`` a
minimum-cost subset runs with reduced step counts (the CI lane that
keeps the perf scripts from rotting); with ``name`` only that module.

  fig1_timeline          Fig. 1: generation-pool utilization sync vs async
  table1_end_to_end      Table 1: sync vs async end-to-end hours
  fig4_scaling           Fig. 4: strong-scaling of effective throughput
  table2_staleness       Table 2 / Fig. 5a-b: REAL staleness x objective runs
  table8_rloo            App. C.4 Table 8: RLOO vs GRPO staleness tolerance
  fig5c_throughput       Fig. 5c / Table 7: throughput vs eta
  fig6a_dynamic_batching Fig. 6a: Algorithm 1 vs static micro-batching
  fig6b_interruptible    Fig. 6b: interruptible-generation ablation
  paged_cache            Paged vs ring KV cache: slots at fixed HBM
  chunked_prefill        Chunked vs monolithic prefill: decode-stall
  async_overlap          Threaded runtime: real gen/train wall-clock overlap
  reward_overlap         Async reward service vs synchronous verification
  fleet_overlap          Process fleet: equivalence, crash recovery, speed
  weight_stream          Streaming delta publication: identity, tokens lost
  decode_speed           Fused decode fast path + self-speculative rounds
  serve_gateway          Serving gateway: SLA load, LRU eviction, recompute
  trace_overhead         Structured tracing: enabled vs disabled throughput
  roofline_report        Roofline terms from the dry-run artifacts

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (async_overlap, chunked_prefill, decode_speed,
                        fig1_timeline, fig4_scaling, fig5c_throughput,
                        fig6a_dynamic_batching, fig6b_interruptible,
                        fleet_overlap, paged_cache, reward_overlap,
                        roofline_report, serve_gateway, table1_end_to_end,
                        table2_staleness, table8_rloo, trace_overhead,
                        weight_stream)
from benchmarks.common import emit

MODULES = [
    ("fig1", fig1_timeline),
    ("table1", table1_end_to_end),
    ("fig4", fig4_scaling),
    ("table2", table2_staleness),
    ("table8", table8_rloo),
    ("fig5c", fig5c_throughput),
    ("fig6a", fig6a_dynamic_batching),
    ("fig6b", fig6b_interruptible),
    ("paged", paged_cache),
    ("chunked", chunked_prefill),
    ("overlap", async_overlap),
    ("reward", reward_overlap),
    ("fleet", fleet_overlap),
    ("wstream", weight_stream),
    ("decode", decode_speed),
    ("gateway", serve_gateway),
    ("trace", trace_overhead),
    ("roofline", roofline_report),
]


# cheapest modules still covering both execution paths: the virtual-time
# simulator/controller stack (fig1) and the real model + packing/PPO
# step path (fig6a); roofline exercises the artifact plumbing; paged
# keeps the paged-cache engine + allocator benchmark from rotting;
# chunked keeps the chunked-prefill engine + stall metric from rotting;
# overlap keeps the threaded disaggregated runtime from rotting (a
# subprocess on 4 fake devices with a hard timeout, so a deadlock fails
# fast instead of hanging the lane); reward keeps the async reward
# service honest AND runs the --env code sandbox subprocess in CI; fleet
# spawns the multi-process executor, kills a worker and checks recovery
# (also a hard-timeout subprocess — supervision bugs fail fast); wstream
# runs the streaming weight-publication identity/stall battery (its
# deterministic stall numbers are gated at zero drift, so the smoke run
# keeps the fixed full schedule there and reduces only the runtime
# sections); decode runs the fused/split/spec trajectory-identity +
# dispatch-count battery (the fast-path engine modes must not rot);
# gateway runs the serving-gateway trace — its banded metrics are
# tick-deterministic, so the smoke run keeps the full fixed schedule
# (same discipline as wstream's stall section); trace bands the
# tracing-enabled / disabled throughput ratio and overlap's traced
# re-run additionally gates a well-formed Perfetto timeline, so the
# telemetry subsystem cannot silently regress serving speed or emit a
# malformed artifact.
SMOKE_MODULES = ("fig1", "fig6a", "paged", "chunked", "overlap", "reward",
                 "fleet", "wstream", "decode", "gateway", "trace",
                 "roofline")


def main() -> None:
    args = sys.argv[1:]
    smoke = "--smoke" in args
    if smoke:
        from benchmarks import common
        common.SMOKE = True
        common.clean_bench_outputs()       # no stale gate inputs
        args = [a for a in args if a != "--smoke"]
    print("name,us_per_call,derived")
    only = args[0] if args else None
    failed = False
    for name, mod in MODULES:
        if only and name != only:
            continue
        if smoke and not only and name not in SMOKE_MODULES:
            continue
        try:
            mod.main()
        except Exception:
            failed = True
            emit(f"{name}_ERROR", 0.0, "see_stderr")
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
