"""§Roofline report: per (arch x shape x mesh) three-term roofline from
the dry-run artifacts (runs/dryrun), with dominant-bottleneck
classification and MODEL_FLOPS/HLO_FLOPs useful-compute ratio."""
from __future__ import annotations

import os

from benchmarks.common import emit
from repro.launch import roofline


def main():
    default = "runs/dryrun_final" if os.path.isdir("runs/dryrun_final") \
        else "runs/dryrun"
    d = os.environ.get("DRYRUN_DIR", default)
    if not os.path.isdir(d):
        emit("roofline_missing", 0.0, f"no_dryrun_artifacts_in_{d}")
        return
    rows = [r for r in roofline.load_rows(d) if r.variant == ""]
    for r in rows:
        if r.status != "ok":
            emit(f"roofline_{r.arch}_{r.shape}_{r.mesh}", 0.0,
                 f"status={r.status}")
            continue
        emit(f"roofline_{r.arch}_{r.shape}_{r.mesh}",
             r.total_s * 1e6,
             f"C={r.compute_s:.2e}s;M={r.memory_s:.2e}s;"
             f"X={r.collective_s:.2e}s;dom={r.dominant};"
             f"useful={r.useful_ratio:.2f};fits={'Y' if r.fits else 'N'}")


if __name__ == "__main__":
    main()
