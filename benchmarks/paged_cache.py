"""Paged vs ring KV cache: concurrent slots at fixed cache HBM.

The rollout worker's throughput is bounded by how many concurrent
decode slots its cache memory sustains (every ``update_weights``
interrupt re-prefills all of them, so slots are the generation
bandwidth).  The ring engine reserves ``max_len`` KV rows per slot
unconditionally; the paged engine (DESIGN.md §Paged KV-cache pool)
reserves ceil(history / block_size) blocks and maps the full prompt
blocks of a GRPO group (paper Table 3: 16 answers per prompt) to
*shared* read-only blocks, so the prompt's KV is stored once per group
instead of once per slot.

This benchmark drives the real ``BlockAllocator`` admission path over a
sweep of HBM budgets and records the admitted-slots curve for both
engines in ``BENCH_paged_cache.json``, plus a wall-clock decode-step
comparison of the two engines on a tiny model (the jnp path; the Pallas
kernels are the TPU version of the same math).

KV-geometry and group size follow the paper's base model
(R1-Distill-Qwen-1.5B: 28 layers, 2 KV heads, head_dim 128, bf16) and
RL config (answers_per_prompt=16, max_prompt_len=1024); the response
budget is the serving/eval regime (512) where cache capacity, not
compute, is the binding constraint.
"""
from __future__ import annotations

import json
import time

from benchmarks.common import bench_path, emit, smoke_steps
from repro.configs import get_model_config
from repro.configs.base import RLConfig
from repro.core.batching import BlockAllocator

BLOCK_SIZE = 16
PROMPT = 1024            # RLConfig.max_prompt_len
GEN = 512                # serving/eval response budget
GROUP = 16               # RLConfig.answers_per_prompt
HBM_BUDGETS_MB = (64, 128, 256, 512, 1024, 2048)


def kv_bytes_per_token(cfg, dtype_bytes: int = 2) -> int:
    """K+V bytes one token occupies across the attention layers."""
    units, rem = cfg.pattern_counts
    seq = list(cfg.block_pattern) * units + list(cfg.block_pattern[:rem])
    n_attn = sum(bt in ("attn", "swa", "local") for bt in seq)
    return n_attn * 2 * cfg.n_kv_heads * cfg.head_dim * dtype_bytes


def ring_slots(hbm_bytes: int, bpt: int) -> int:
    return int(hbm_bytes // ((PROMPT + GEN) * bpt))


def paged_slots(hbm_bytes: int, bpt: int) -> int:
    """Greedy group admission through the real allocator until the pool
    is exhausted (the engine's exact reservation math: worst-case blocks
    per slot, full prompt blocks shared within a group)."""
    n_blocks = int(hbm_bytes // (BLOCK_SIZE * bpt))
    if n_blocks <= 0:
        return 0
    alloc = BlockAllocator(n_blocks, BLOCK_SIZE)
    need = -(-(PROMPT + GEN - 1) // BLOCK_SIZE)
    slots = 0
    gi = 0
    while True:
        prompt = [gi] * PROMPT                        # distinct per group
        gi += 1
        for _ in range(GROUP):
            n_full = PROMPT // BLOCK_SIZE
            try:
                prefix, _ = alloc.plan_prefix(0, prompt)
                if alloc.n_free < need - n_full:
                    for b in prefix:
                        alloc.release(b)
                    return slots
                for _ in range(need - n_full):
                    alloc.alloc(0)
            except MemoryError:
                return slots
            slots += 1


def decode_step_us(cache: str, steps: int) -> float:
    """Wall time per decode step of the real engine on a tiny model."""
    import dataclasses

    import jax

    from repro.configs import reduced
    from repro.core import EngineConfig, RolloutEngine
    from repro.data import tokenizer
    from repro.models.model import build_model

    cfg = dataclasses.replace(reduced(get_model_config("areal-qwen-1.5b")),
                              vocab_size=tokenizer.VOCAB_SIZE)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    eng = RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=8, prompt_len=16, max_gen_len=steps + 2, temperature=-1.0,
        seed=0, cache=cache, block_size=BLOCK_SIZE))
    prompt = list(range(1, 13))
    eng.admit([{"rid": i, "prompt_id": 0, "prompt": prompt, "answer": None}
               for i in range(8)])
    eng.step()                                        # compile
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    return (time.perf_counter() - t0) / steps * 1e6


def main() -> None:
    cfg = get_model_config("areal-qwen-1.5b")
    rl = RLConfig()
    assert rl.answers_per_prompt == GROUP and rl.max_prompt_len == PROMPT
    bpt = kv_bytes_per_token(cfg)

    curve = []
    for mb in HBM_BUDGETS_MB:
        hbm = mb * 2**20
        r = ring_slots(hbm, bpt)
        p = paged_slots(hbm, bpt)
        curve.append({"hbm_mb": mb, "ring_slots": r, "paged_slots": p,
                      "ratio": round(p / r, 3) if r else None})
    ratios = [c["ratio"] for c in curve if c["ratio"]]
    min_ratio = min(ratios)

    steps = smoke_steps(32, 2)
    us_ring = decode_step_us("ring", steps)
    us_paged = decode_step_us("paged", steps)

    record = {
        "model": cfg.name,
        "kv_bytes_per_token": bpt,
        "block_size": BLOCK_SIZE,
        "prompt_len": PROMPT, "gen_len": GEN, "group_size": GROUP,
        "curve": curve,
        "min_slots_ratio": min_ratio,
        "decode_step_us": {"ring": round(us_ring, 1),
                           "paged": round(us_paged, 1)},
    }
    with open(bench_path("BENCH_paged_cache.json"), "w") as f:
        json.dump(record, f, indent=2)

    emit("paged_cache_slots", us_paged, f"slots_x{min_ratio:.2f}")
    emit("paged_cache_decode_ring", us_ring, "us_per_step")


if __name__ == "__main__":
    main()
