"""Decode fast path: fused single-dispatch steps and self-speculative
multi-token rounds, with a per-family tokens/s-vs-roofline gap table.

Three arms, all on the SAME tiny model so CI smoke stays cheap:

* **fused** — the paged engine in its three dispatch modes (DESIGN.md
  §Fused decode tail): ``default`` (one jitted step returning sampled
  tokens), ``fused`` (same single dispatch through the hoisted
  block-table gather + fused attention/projection tail) and ``split``
  (logits and sampling as two dispatches — the measurement baseline).
  All three must produce bit-identical trajectories; the gate bands
  ``throughput_ratio`` (fused vs split — median of position-paired
  per-step wall ratios, the modes driven in step-level lockstep) >= 1.0
  and ``dispatches_per_step`` == 1.0 for fused.

* **spec** — self-speculative decoding (DESIGN.md §Self-speculative
  decoding) under *controlled acceptance*: the last unit's ``wo`` /
  ``w_down`` are zeroed, making it an identity on the residual stream,
  so the truncated draft pass agrees with the full model and every
  draft is accepted.  The spec engine must be trajectory-identical to
  the plain greedy engine on the SAME zeroed params, and
  ``accepted_tokens_per_step`` (committed tokens per member-dispatch,
  1.0 = plain decode) must clear its floor.

* **families** — measured decode tokens/s for one representative of
  each architecture family (transformer / RG-LRU / xLSTM) next to the
  analytic memory-bound roofline (weights + decode state re-read per
  token, ``launch/roofline.py::decode_gap_rows``).  On CPU the gap vs
  the TPU-v5e ceiling is tiny; the gate only bands it into (0, 1].

Results land in ``BENCH_decode_speed.json`` via ``bench_path`` (smoke
runs never clobber the committed full-run baseline).  Each timed mode
builds ONE engine and runs a warmup batch on it first: the engine's jit
wrappers are per-instance, so a fresh engine per repeat would put
seconds of tracing — with far more variance than the ~5% steady-state
margin being gated — inside every timed window.  The timed batches then
reuse the warmed engine (pure steady-state dispatch); the fused arm
additionally drives its three modes in step-level lockstep and gates
the median of position-paired per-step wall ratios, so host drift and
background bursts hit both sides of every pair — the gated numbers are
*ratios* between modes, and timing the modes in separate blocks would
let background noise alone push them over a band.
"""
from __future__ import annotations

import json
import statistics
import time

from benchmarks.common import bench_path, emit

N_SLOTS = 4
PROMPT_LEN = 16
MAX_GEN = 16
N_REQUESTS = 24
SPEC_K = 4
# Smoke runs use the same counts as full runs: engine builds/compiles
# dominate this module's cost either way, and the timed steady-state
# batches are milliseconds — shrinking them only adds noise to the
# gated fused/split ratio.
REPEATS = 8


# The tiny tokenizer vocab (~50 ids) would make the split path's extra
# dispatch nearly free: the logits crossing the jit boundary are the
# traffic the fused tail exists to avoid, so the bench uses an LM-scale
# vocab (prompt ids stay inside the tokenizer range).
VOCAB = 8192


def _cfg(family: str = "dense"):
    from repro.configs.base import ModelConfig

    if family == "dense":
        return ModelConfig(name="bench-decode", family="dense", n_layers=3,
                           d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
                           vocab_size=VOCAB)
    if family == "hybrid":                 # RG-LRU + local attention
        return ModelConfig(name="bench-decode-rec", family="hybrid",
                           n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
                           d_ff=96, vocab_size=VOCAB,
                           block_pattern=("rec", "local"), local_window=8)
    return ModelConfig(name="bench-decode-xlstm", family="ssm", n_layers=2,
                       d_model=48, n_heads=4, n_kv_heads=4, d_ff=0,
                       vocab_size=VOCAB,
                       block_pattern=("mlstm", "slstm"))


def _build(cfg, seed: int = 0, **engine_kw):
    import jax

    from repro.core.config import EngineConfig
    from repro.core.rollout import RolloutEngine
    from repro.models.model import build_model

    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(seed))
    if engine_kw.pop("zero_last_unit", False):
        params = _zero_last_unit(params)
    eng = RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=N_SLOTS, prompt_len=PROMPT_LEN, max_gen_len=MAX_GEN,
        seed=seed, rng="request", **engine_kw))
    return model, params, eng


def _zero_last_unit(params):
    """Zero the last stacked unit's attention output projection and MLP
    down-projection: with pre-norm residual blocks that unit becomes an
    identity on the residual stream, so the truncated draft pass (all
    units but the last) agrees with the full model exactly — controlled
    100% draft acceptance without changing any other unit."""
    units = []
    for blk in params["units"]:
        blk = dict(blk)
        if "attn" in blk:
            a = dict(blk["attn"])
            a["wo"] = a["wo"].at[-1].set(0.0)
            blk["attn"] = a
        if "mlp" in blk:
            m = dict(blk["mlp"])
            m["w_down"] = m["w_down"].at[-1].set(0.0)
            blk["mlp"] = m
        units.append(blk)
    out = dict(params)
    out["units"] = tuple(units)
    return out


def _requests(n, base: int = 0):
    return [{"rid": base + i, "prompt_id": base + i,
             "prompt": [1 + (5 * (base + i) + j) % 40
                        for j in range(PROMPT_LEN)],
             "answer": None} for i in range(n)]


def _drive(eng, n_requests: int, base: int = 0):
    """Run one request batch to completion on ``eng``.  Returns (wall_s,
    tokens, decode_steps, dispatches, responses, step_walls), all deltas
    for THIS batch: decode_steps counts engine steps that committed at
    least one token (a spec draft step commits none), and step_walls
    holds each such step's individual wall seconds."""
    done, decode_steps, step = 0, 0, 0
    pending = _requests(n_requests, base)
    responses = {}
    step_walls = []
    tokens0, dispatch0 = eng.tokens_generated, eng.decode_dispatches
    t0 = time.perf_counter()
    while done < n_requests:
        n = eng.admit(pending)
        pending = pending[n:]
        before = eng.tokens_generated
        t1 = time.perf_counter()
        finished = eng.step()
        dt = time.perf_counter() - t1
        if eng.tokens_generated > before:
            decode_steps += 1
            step_walls.append(dt)
        for f in finished:
            done += 1
            responses[f.rid] = tuple(f.response)
        step += 1
        assert step < 50_000, "decode benchmark did not converge"
    return (time.perf_counter() - t0, eng.tokens_generated - tokens0,
            decode_steps, eng.decode_dispatches - dispatch0, responses,
            step_walls)


def _record(wall, tokens, decode_steps, dispatches):
    return {
        "wall_s": round(wall, 4),
        "tokens": tokens,
        "throughput_tok_s": round(tokens / wall, 2),
        "decode_dispatches": dispatches,
        "dispatches_per_step": round(dispatches / max(1, decode_steps), 3),
    }


def _measure_many(cfg, n_requests: int, repeats: int, mode_kws: dict):
    """Build ONE engine per mode, run a warmup batch on it (the engine's
    per-instance jit wrappers trace here), then time ``repeats`` further
    request batches on the SAME engine and keep each mode's fastest —
    the timed region is pure steady-state dispatch, no tracing.  Batches
    are interleaved round-robin across modes and use the same request
    ids in every mode; with ``rng="request"`` a trajectory is a pure
    function of (seed, rid), so matching batches across modes must
    match bit-for-bit."""
    engines = {mode: _build(cfg, **kw)[2] for mode, kw in mode_kws.items()}
    for eng in engines.values():
        _drive(eng, n_requests)                          # warmup
    best = dict.fromkeys(mode_kws)
    resp = {mode: {} for mode in mode_kws}
    step_walls = {mode: [] for mode in mode_kws}
    for r in range(1, repeats + 1):
        for mode, eng in engines.items():
            wall, tokens, steps, dispatches, responses, walls = \
                _drive(eng, n_requests, base=r * n_requests)
            resp[mode].update(responses)
            step_walls[mode].extend(walls)
            if best[mode] is None or wall < best[mode][0]:
                best[mode] = (wall, tokens, steps, dispatches)
    out = {}
    for mode in mode_kws:
        rec = _record(*best[mode])
        rec["median_step_ms"] = round(
            statistics.median(step_walls[mode]) * 1e3, 4)
        out[mode] = (rec, resp[mode], engines[mode], step_walls[mode])
    return out


def _drive_lockstep(engines: dict, n_requests: int, base: int = 0):
    """Drive one request batch through every engine in step-level
    lockstep: mode A's step ``i`` runs microseconds before mode B's
    step ``i``, so position-paired timings share the same host
    conditions (CPU frequency, cache pressure, background load) and a
    paired ratio cancels drift that defeats any comparison of
    per-mode aggregates taken seconds apart.  All modes follow the
    identical deterministic schedule, so positions align exactly.
    Returns per mode: (wall_s, tokens, decode_steps, dispatches,
    responses, step_walls), deltas for THIS batch."""
    state = {mode: {"pending": _requests(n_requests, base), "done": 0,
                    "walls": [], "resp": {}, "wall": 0.0, "steps": 0,
                    "tokens0": eng.tokens_generated,
                    "dispatch0": eng.decode_dispatches}
             for mode, eng in engines.items()}
    rounds = 0
    while any(s["done"] < n_requests for s in state.values()):
        for mode, eng in engines.items():
            s = state[mode]
            if s["done"] >= n_requests:
                continue
            t0 = time.perf_counter()
            n = eng.admit(s["pending"])
            before = eng.tokens_generated
            finished = eng.step()
            dt = time.perf_counter() - t0
            s["pending"] = s["pending"][n:]
            s["wall"] += dt
            if eng.tokens_generated > before:
                s["steps"] += 1
                s["walls"].append(dt)
            for f in finished:
                s["done"] += 1
                s["resp"][f.rid] = tuple(f.response)
        rounds += 1
        assert rounds < 50_000, "decode benchmark did not converge"
    return {mode: (s["wall"], engines[mode].tokens_generated - s["tokens0"],
                   s["steps"],
                   engines[mode].decode_dispatches - s["dispatch0"],
                   s["resp"], s["walls"])
            for mode, s in state.items()}


def _paired_step_ratio(num_rounds, den_rounds):
    """Median over every position-paired per-step wall ratio (hundreds
    of samples), the statistic robust enough to gate a few-percent
    systematic margin: a best-wall quotient compares two extreme order
    statistics, and unpaired medians drift with the host between the
    modes' runs."""
    return statistics.median(
        n / d
        for nr, dr in zip(num_rounds, den_rounds)
        for n, d in zip(nr, dr))


def _measure(cfg, n_requests: int, repeats: int, **engine_kw):
    return _measure_many(cfg, n_requests, repeats, {"_": engine_kw})["_"][:3]


def _fused_arm(n_requests: int, repeats: int):
    cfg = _cfg("dense")
    engines = {
        "default": _build(cfg, cache="paged")[2],
        "fused": _build(cfg, cache="paged", fused_decode="fused")[2],
        "split": _build(cfg, cache="paged", fused_decode="split")[2],
    }
    _drive_lockstep(engines, n_requests)                 # warmup
    best = dict.fromkeys(engines)
    resp = {m: {} for m in engines}
    round_walls = {m: [] for m in engines}
    for r in range(1, repeats + 1):
        out = _drive_lockstep(engines, n_requests, base=r * n_requests)
        for m, (wall, tokens, steps, dispatches, responses, walls) in \
                out.items():
            resp[m].update(responses)
            round_walls[m].append(walls)
            if best[m] is None or wall < best[m][0]:
                best[m] = (wall, tokens, steps, dispatches)
    modes = {}
    for m in engines:
        modes[m] = _record(*best[m])
        modes[m]["median_step_ms"] = round(statistics.median(
            w for rw in round_walls[m] for w in rw) * 1e3, 4)
    identical = resp["default"] == resp["fused"] == resp["split"]
    assert identical, "fused/split/default decode trajectories diverged"
    ratio = _paired_step_ratio(round_walls["split"], round_walls["fused"])
    return {
        **modes,
        "throughput_ratio": round(ratio, 3),
        "dispatches_per_step": modes["fused"]["dispatches_per_step"],
        "trajectories_identical": identical,
    }


def _spec_arm(n_requests: int, repeats: int):
    cfg = _cfg("dense")
    runs = _measure_many(cfg, n_requests, repeats, {
        "baseline": {"cache": "paged", "temperature": 0.0,
                     "zero_last_unit": True},
        "spec": {"cache": "paged", "temperature": 0.0,
                 "zero_last_unit": True, "spec_decode": SPEC_K},
    })
    base, base_resp, _ = runs["baseline"][:3]
    spec, spec_resp, eng = runs["spec"][:3]
    identical = base_resp == spec_resp
    assert identical, "speculative trajectories diverged from greedy baseline"
    return {
        "k": SPEC_K,
        "baseline": base,
        "spec": spec,
        "accepted_tokens_per_step": round(eng.accepted_tokens_per_step, 3),
        "draft_acceptance_rate": round(eng.draft_acceptance_rate, 3),
        "throughput_ratio": round(
            spec["throughput_tok_s"] / max(base["throughput_tok_s"], 1e-9), 3),
        "trajectories_identical": identical,
    }


def _family_arm(n_requests: int, repeats: int):
    import jax
    import jax.numpy as jnp

    from repro.launch.roofline import decode_gap_rows

    families = {}
    for fam, key in (("dense", "transformer"), ("hybrid", "rg-lru"),
                     ("ssm", "xlstm")):
        cfg = _cfg(fam)
        rec, _, eng = _measure(cfg, n_requests, repeats)
        model = eng.model
        param_bytes = sum(x.size * x.dtype.itemsize
                          for x in jax.tree.leaves(eng.params))
        state = jax.eval_shape(
            lambda m=model: m.init_cache(1, PROMPT_LEN + MAX_GEN, jnp.float32))
        state_bytes = sum(
            x.size * jnp.dtype(x.dtype).itemsize
            for x in jax.tree.leaves(state))
        families[key] = {
            **rec,
            "tokens_per_s": rec["throughput_tok_s"],
            "param_bytes": param_bytes,
            "state_bytes": state_bytes,
            "bytes_per_token": param_bytes + state_bytes,
        }
    for row in decode_gap_rows({"families": families}):
        families[row["family"]]["roofline_tok_s"] = row["roofline_tok_s"]
        families[row["family"]]["measured_over_roofline"] = \
            row["measured_over_roofline"]
    return families


def main() -> None:
    n_requests = N_REQUESTS
    repeats = REPEATS
    fused = _fused_arm(n_requests, repeats)
    spec = _spec_arm(n_requests, repeats)
    families = _family_arm(n_requests, repeats)
    record = {
        "config": {"n_slots": N_SLOTS, "prompt_len": PROMPT_LEN,
                   "max_gen_len": MAX_GEN, "n_requests": n_requests,
                   "spec_k": SPEC_K, "repeats": repeats},
        "fused": fused,
        "spec": spec,
        "families": families,
    }
    with open(bench_path("BENCH_decode_speed.json"), "w") as f:
        json.dump(record, f, indent=2)

    emit("decode_fused_step",
         fused["fused"]["wall_s"] / max(fused["fused"]["tokens"], 1) * 1e6,
         f"tput_x{fused['throughput_ratio']:.2f}_vs_split")
    emit("decode_spec_accept",
         spec["spec"]["wall_s"] / max(spec["spec"]["tokens"], 1) * 1e6,
         f"accepted_per_step{spec['accepted_tokens_per_step']:.2f}")


if __name__ == "__main__":
    main()
