"""Streaming delta weight publication: identity, tokens lost, latency.

The streaming publication path (core/weights.py, DESIGN.md §Streaming
weight publication) ships each trainer update as a sequence of per-leaf
delta chunks that the rollout engine applies under a version fence
(DESIGN.md §Version fence): chunks for later layers decode and stage
host→device WHILE the engine keeps generating under the last complete
version, and the flip to the new version is a single ordinary
``update_weights`` once the stream completes.  This benchmark proves the
four properties the design claims, on a real (tiny) model:

  * **identity** — unquantized streaming is bit-for-bit
    trajectory-identical to a monolithic full-tree update applied at the
    same step boundary, across the engine matrix {ring, paged} x
    {monolithic, chunked prefill} (XOR deltas are exact for every dtype;
    the fence confines all stream effects to the flip step);
  * **stall** — under a fixed transport budget of ONE chunk per engine
    step opportunity, a monolithic publication occupies the engine for
    the full tree's chunk count (the generation pool stalls, as in the
    paper's Fig. 6b non-interruptible baseline), while the streamed
    publication feeds one chunk per opportunity alongside decoding and
    loses (here) zero tokens — tokens-lost-per-update and
    publication-to-pickup latency both drop by the full/delta chunk
    ratio, at no throughput cost.  All numbers in this section are
    deterministic (fixed schedule, no threads) and gated at zero drift;
  * **quantized** — ``delta-q`` (int8 + per-chunk scale) decodes within
    the stream's own declared tolerance, and IS lossy (the exact-XOR
    path is what the identity section runs);
  * **runtimes** — the real executors reproduce the section-level
    claims: ``ThreadedRuntime(weight_stream="delta")`` matches the full
    publication path trajectory-for-trajectory (lr=0 frozen params), and
    a fleet rollout worker SIGKILLed MID-STREAM leaves a fleet that
    still completes with zero lost/duplicated trajectories and
    bit-identical outputs — the torn partial version is discarded, never
    applied (DESIGN.md §Torn-stream recovery).

One subprocess runs every section (2 fake host devices, hard timeout).
Results land in ``BENCH_weight_stream.json``; the gated metrics
(tools/check_bench.py) are the identity booleans, the stall section's
zero-drift token/latency numbers, ``stall.tokens_lost_ratio`` and the
fleet-kill recovery fields.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import bench_path, emit, smoke_steps

DEVICES = 2
RUN_TIMEOUT = 600.0

# identity section: flip boundary + decode window (fixed, deterministic)
IDENT_FLIP_AT = 6
IDENT_STEPS = 60

# stall section: S decode opportunities, publications at fixed indices,
# transport budget 1 chunk/opportunity (fixed even in smoke mode: the
# whole section is a few hundred tiny decode steps and its numbers are
# gated at zero drift, so smoke must reproduce them exactly)
STALL_OPPS = 120
STALL_PUBLISH_AT = (20, 70)
STALL_CHUNK_ELEMS = 8192

THR_STEPS = 2
KILL_STEPS = 3


def _cfg():
    from repro.configs.base import ModelConfig
    from repro.data import tokenizer
    return ModelConfig(name="bench-wstream", family="dense", n_layers=1,
                       d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                       vocab_size=tokenizer.VOCAB_SIZE)


def _rl(lr: float = 0.0):
    from repro.configs.base import RLConfig
    return RLConfig(batch_size=4, answers_per_prompt=2, max_staleness=2,
                    interruptible=True, ppo_minibatches=1,
                    microbatch_token_budget=64, lr=lr,
                    max_prompt_len=16, max_gen_len=8)


# module-level so multiprocessing spawn can pickle them by reference
def engine_factory(*, seed: int = 0, n_slots: int = 2):
    from repro.core.fleet import build_engine
    return build_engine(model_cfg=_cfg(), seed=seed,
                        engine_kwargs=dict(n_slots=n_slots, prompt_len=16,
                                           max_gen_len=8, rng="request"))


def trainer_factory(*, seed: int = 0, lr: float = 0.0):
    from repro.core.fleet import build_trainer
    return build_trainer(model_cfg=_cfg(), rl=_rl(lr), seed=seed)


def _sched(lr: float = 0.0):
    from repro.core import AsyncScheduler
    from repro.env import EnvPromptStream, MathEnv
    return AsyncScheduler(
        prompt_stream=EnvPromptStream(MathEnv(seed=3, max_operand=9),
                                      answers_per_prompt=2),
        rl=_rl(lr), env=MathEnv(seed=3, max_operand=9))


def _capture(sched):
    cap = []
    orig = sched.record_consumed

    def wrapper(batch):
        cap.extend(batch)
        return orig(batch)

    sched.record_consumed = wrapper
    return cap


def _by_rid(cap):
    return {t.rid: (tuple(t.prompt_tokens), tuple(t.response_tokens))
            for t in cap}


# ---- engine-level plumbing (identity + stall sections) ----------------------
def _model_and_params(seed: int = 0):
    import jax

    from repro.models.model import build_model
    model = build_model(_cfg(), remat=False)
    return model, model.init(jax.random.key(seed))


def _perturb(params, seed: int):
    """A REAL weight update, deterministic and sparse: every third float
    leaf moves by small gaussian noise (sparse so the delta stream is
    much smaller than the full tree — the common case one PPO step in)."""
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree.flatten(params)
    key = jax.random.key(1000 + seed)
    out = []
    for i, leaf in enumerate(leaves):
        if i % 3 == 0 and jnp.issubdtype(leaf.dtype, jnp.floating):
            k = jax.random.fold_in(key, i)
            out.append(leaf + 1e-3 * jax.random.normal(k, leaf.shape,
                                                       leaf.dtype))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _requests(n: int):
    return [{"rid": i, "prompt_id": i,
             "prompt": [2 + (7 * i + j) % 50 for j in range(8)],
             "answer": None} for i in range(n)]


def _engine(model, params, *, cache: str, prefill_chunk: int,
            max_gen_len: int = 16, n_slots: int = 4, eos_id: int = -1):
    from repro.core.config import EngineConfig
    from repro.core.rollout import RolloutEngine
    return RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=n_slots, prompt_len=16, max_gen_len=max_gen_len, seed=7,
        eos_id=eos_id, cache=cache, prefill_chunk=prefill_chunk,
        rng="request"))


def _identity_one(model, params0, params1_dev, msgs, *, cache: str,
                  prefill_chunk: int):
    """One (cache, prefill) config: run the monolithic-update baseline
    and the streamed run with the flip at the SAME step boundary; return
    per-rid (prompt, response, logprobs) for exact comparison."""
    import math

    def run(streamed: bool):
        eng = _engine(model, params0, cache=cache,
                      prefill_chunk=prefill_chunk)
        eng.admit(_requests(4))
        done = []
        pending = list(msgs)
        per_step = max(1, math.ceil((len(pending) - 1) / IDENT_FLIP_AT))
        for step in range(IDENT_STEPS):
            if streamed:
                if step < IDENT_FLIP_AT:
                    # chunks apply under decode of the old version: the
                    # fence keeps them out of the trajectories
                    for _ in range(per_step):
                        if len(pending) > 1:      # hold StreamEnd
                            eng.feed_weight_message(pending.pop(0))
                elif step == IDENT_FLIP_AT:
                    while pending:                # End included -> flip
                        eng.feed_weight_message(pending.pop(0))
                    assert eng.version == 1, eng.version
            elif step == IDENT_FLIP_AT:
                eng.update_weights(params1_dev, 1)
            done.extend(eng.step())
            if not eng.n_active:
                break
        return {f.rid: (tuple(f.prompt), tuple(f.response),
                        tuple(f.logprobs)) for f in done}

    base = run(streamed=False)
    stream = run(streamed=True)
    return {
        "n_finished": len(base),
        "n_finished_streamed": len(stream),
        "identical": bool(len(base) == 4 and base == stream),
    }


def _identity():
    import jax

    from repro.core.weights import encode_stream
    from repro.launch.disaggregated import host_weights

    model, params0 = _model_and_params()
    params1 = _perturb(params0, 1)
    stream = encode_stream(host_weights(params1), version=1,
                           base=host_weights(params0), base_version=0,
                           encoding="delta", chunk_elems=512)
    msgs = list(stream)
    params1_dev = jax.tree.map(jax.numpy.asarray, params1)
    out = {"stream_messages": len(msgs)}
    for cache in ("ring", "paged"):
        for pc, label in ((0, "monolithic"), (4, "chunked")):
            out[f"{cache}_{label}"] = _identity_one(
                model, params0, params1_dev, msgs, cache=cache,
                prefill_chunk=pc)
    out["all_identical"] = all(
        v["identical"] for k, v in out.items() if isinstance(v, dict))
    return out


def _stall():
    """Deterministic stall model, fixed transport budget of ONE chunk
    per decode opportunity.  Monolithic publication: the engine is
    occupied for the full tree's chunk count before it can flip (C_full
    stalled opportunities, G slots -> C_full*G tokens lost per update).
    Streamed: one chunk feeds per opportunity ALONGSIDE the decode step
    and the engine flips as soon as the (much shorter) delta stream
    completes.  A reference run with no updates bounds the token budget;
    everything is single-threaded and schedule-fixed, so the gate holds
    these numbers at zero drift."""
    import jax

    from repro.core.weights import encode_stream
    from repro.launch.disaggregated import host_weights

    model, params0 = _model_and_params()
    versions = [params0]
    for u in range(len(STALL_PUBLISH_AT)):
        versions.append(_perturb(versions[-1], u + 1))
    hosts = [host_weights(p) for p in versions]
    full_chunks = [encode_stream(hosts[u + 1], version=u + 1, base=None,
                                 chunk_elems=STALL_CHUNK_ELEMS).n_chunks
                   for u in range(len(STALL_PUBLISH_AT))]
    delta_streams = [encode_stream(hosts[u + 1], version=u + 1,
                                   base=hosts[u], base_version=u,
                                   encoding="delta",
                                   chunk_elems=STALL_CHUNK_ELEMS)
                     for u in range(len(STALL_PUBLISH_AT))]
    n_updates = len(STALL_PUBLISH_AT)

    def fresh():
        eng = _engine(model, params0, cache="ring", prefill_chunk=0,
                      max_gen_len=STALL_OPPS + 8)
        eng.admit(_requests(4))
        return eng

    # reference: every opportunity decodes, no publication
    ref = fresh()
    for _ in range(STALL_OPPS):
        ref.step()

    # monolithic: each publication occupies C_full opportunities
    # (transfer at 1 chunk/opportunity, applied whole) before the flip
    full = fresh()
    stall_left = 0
    pending_flip = None
    schedule = dict(zip(STALL_PUBLISH_AT, range(1, n_updates + 1)))
    flip_opps = []
    for opp in range(STALL_OPPS):
        if opp in schedule:
            u = schedule[opp]
            stall_left = full_chunks[u - 1]
            pending_flip = u
        if stall_left > 0:
            stall_left -= 1
            if stall_left == 0 and pending_flip is not None:
                full.update_weights(
                    jax.tree.map(jax.numpy.asarray, versions[pending_flip]),
                    pending_flip)
                flip_opps.append(opp)
                pending_flip = None
            continue                      # the stalled opportunity
        full.step()

    # streamed: one chunk per opportunity feeds alongside the decode
    delta = fresh()
    pending = []
    delta_flip_opps = []
    publish_opps = {}
    for opp in range(STALL_OPPS):
        if opp in schedule:
            u = schedule[opp]
            pending.extend(delta_streams[u - 1])
            publish_opps[u] = opp
        if pending:
            flipped = delta.feed_weight_message(pending.pop(0))
            if flipped:
                delta_flip_opps.append(opp)
        delta.step()
    assert delta.version == n_updates, delta.version
    assert full.version == n_updates, full.version

    lost_full = ref.tokens_generated - full.tokens_generated
    lost_delta = ref.tokens_generated - delta.tokens_generated
    delta_latency = [delta_flip_opps[u - 1] - publish_opps[u]
                     for u in schedule.values()]
    return {
        "opportunities": STALL_OPPS,
        "updates": n_updates,
        "ref_tokens": int(ref.tokens_generated),
        "full_tokens": int(full.tokens_generated),
        "delta_tokens": int(delta.tokens_generated),
        "chunks_full_per_update": sum(full_chunks) / n_updates,
        "chunks_delta_per_update": sum(s.n_chunks for s in delta_streams)
        / n_updates,
        "tokens_lost_full_per_update": lost_full / n_updates,
        "tokens_lost_delta_per_update": lost_delta / n_updates,
        "tokens_lost_ratio": lost_full / max(lost_delta, 1),
        "throughput_ratio": round(delta.tokens_generated
                                  / max(full.tokens_generated, 1), 3),
        # publication -> pickup, in decode opportunities: monolithic
        # waits out the whole transfer; streamed flips at stream end
        "full_latency_steps": sum(full_chunks) / n_updates,
        "delta_latency_steps": sum(delta_latency) / n_updates,
    }


def _quantized():
    import numpy as np

    from repro.core.weights import StreamDecoder, encode_stream, tree_items
    from repro.launch.disaggregated import host_weights

    model, params0 = _model_and_params()
    host0 = host_weights(params0)
    host1 = host_weights(_perturb(params0, 1))
    exact = encode_stream(host1, version=1, base=host0, base_version=0,
                          encoding="delta", chunk_elems=2048)
    q = encode_stream(host1, version=1, base=host0, base_version=0,
                      encoding="delta-q", chunk_elems=2048)
    dec = StreamDecoder(host0, 0)
    out = None
    for msg in q:
        out = dec.feed(msg) or out
    assert out is not None and out[0] == 1
    want = dict(tree_items(host1))
    err = max(float(np.max(np.abs(np.asarray(got) - want[path])))
              if np.asarray(got).size else 0.0
              for path, got in tree_items(out[1]))
    tol = q.tolerance()
    return {
        "max_abs_error": err,
        "declared_tolerance": tol,
        "within_tolerance": bool(err <= tol * (1 + 1e-6)),
        "lossy": bool(err > 0.0),
        "exact_stream_bytes": exact.nbytes(),
        "quantized_stream_bytes": q.nbytes(),
        "bytes_ratio": round(exact.nbytes() / max(q.nbytes(), 1), 3),
    }


def _threaded(sched, weight_stream: str = "full"):
    from repro.core import ThreadedRuntime
    return ThreadedRuntime(engine=engine_factory(n_slots=4),
                           trainer=trainer_factory(), scheduler=sched,
                           weight_stream=weight_stream,
                           stream_chunk_elems=512)


def _threaded_identity(steps: int):
    """ThreadedRuntime full vs delta publication on lr=0 frozen params:
    per-request RNG makes every trajectory a pure function of (seed,
    rid, params), so the two publication transports must produce
    identical trajectories on the common request ids."""
    sched = _sched()
    ref_cap = _capture(sched)
    rt = _threaded(sched, "full")
    rt.run(steps, timeout=RUN_TIMEOUT)
    ref = _by_rid(ref_cap)

    sched = _sched()
    cap = _capture(sched)
    srt = _threaded(sched, "delta")
    srt.run(steps, timeout=RUN_TIMEOUT)
    got = _by_rid(cap)
    common = sorted(set(ref) & set(got))
    ss = srt.engine.stream_stats()
    return {
        "steps": steps,
        "n_common": len(common),
        "trajectories_identical": bool(
            common and all(ref[r] == got[r] for r in common)),
        "streams_completed": ss["streams_completed"],
        "streams_torn": ss["streams_torn"],
        "publication": sched.publication_stats(),
    }


def _fleet_kill(steps: int):
    """SIGKILL a fleet rollout worker MID-STREAM (the first publication
    is base-free, so at stream_chunk_elems=64 it is hundreds of chunk
    messages fed one per engine loop — a wide kill window).  The fleet
    must requeue the victim's slots, respawn, resynchronize the
    replacement with a full tree at registration, and finish with
    trajectories bit-identical to a single-process reference — proof no
    torn partial version was ever applied (DESIGN.md §Torn-stream
    recovery)."""
    import signal
    import threading
    import time

    from repro.core import FleetRuntime

    sched = _sched()
    ref_cap = _capture(sched)
    rt = _threaded(sched, "full")
    rt.run(steps, timeout=RUN_TIMEOUT)
    ref = _by_rid(ref_cap)

    sched = _sched()
    cap = _capture(sched)
    frt = FleetRuntime(scheduler=sched, engine_factory=engine_factory,
                       engine_factory_kwargs={},
                       trainer_factory=trainer_factory,
                       trainer_factory_kwargs={}, n_slots=2,
                       rollout_workers=2, heartbeat_s=0.05,
                       heartbeat_timeout=30.0, weight_stream="delta",
                       stream_chunk_elems=64, stream_chunks_per_step=1)
    killed = {}

    def killer():
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline and not killed:
            for h in frt.registry.ready("rollout"):
                if (h.stats.get("stream_chunks_received", 0) >= 1
                        and frt.sched.inflight_of(h.worker_id)):
                    killed["pid"] = h.proc.pid
                    killed["chunks_fed"] = h.stats["stream_chunks_received"]
                    os.kill(h.proc.pid, signal.SIGKILL)
                    return
            time.sleep(0.005)

    threading.Thread(target=killer, daemon=True).start()
    try:
        frt.run(steps, timeout=RUN_TIMEOUT)
    finally:
        frt.close()
    got = _by_rid(cap)
    rids = [t.rid for t in cap]
    common = sorted(set(ref) & set(got))
    expected = steps * frt.rl.batch_size
    return {
        "steps": steps,
        "killed": bool(killed),
        "chunks_fed_at_kill": killed.get("chunks_fed", 0),
        "completed": bool(frt.version >= steps and killed),
        "requeued": frt.requeued,
        "respawns": frt.respawns,
        "duplicates": frt.duplicates_dropped + (len(rids) - len(set(rids))),
        "lost": expected - len(rids),
        "n_common": len(common),
        "trajectories_identical": bool(
            common and all(ref[r] == got[r] for r in common)),
    }


def _child(thr_steps: int, kill_steps: int) -> None:
    import jax

    out = {"devices": len(jax.devices()),
           "identity": _identity(),
           "stall": _stall(),
           "quantized": _quantized(),
           "threaded": _threaded_identity(thr_steps),
           "fleet_kill": _fleet_kill(kill_steps)}
    print("BENCH_JSON=" + json.dumps(out), flush=True)


def main() -> None:
    thr_steps = smoke_steps(THR_STEPS, 1)
    kill_steps = smoke_steps(KILL_STEPS, 2)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.weight_stream", "--child",
         str(thr_steps), str(kill_steps)],
        capture_output=True, text=True, env=env, timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("BENCH_JSON=")][-1]
    rec = json.loads(line[len("BENCH_JSON="):])
    with open(bench_path("BENCH_weight_stream.json"), "w") as f:
        json.dump(rec, f, indent=2)

    st = rec["stall"]
    emit("weight_stream_stall",
         st["tokens_lost_full_per_update"],
         f"lost_ratio_x{st['tokens_lost_ratio']:.1f}"
         f"_latency_{st['delta_latency_steps']:.0f}"
         f"of{st['full_latency_steps']:.0f}steps")
    emit("weight_stream_identity",
         rec["identity"]["stream_messages"] * 1.0,
         f"identical_{rec['identity']['all_identical']}"
         f"_killmid_{rec['fleet_kill']['trajectories_identical']}")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]), int(sys.argv[3]))
    else:
        main()
