"""Fleet executor: process-parallel rollout with supervised recovery.

The fleet runtime (core/fleet.py, DESIGN.md §Fleet runtime) runs N
rollout workers and M trainer replicas as OS processes under a
supervising parent.  This benchmark proves the three properties the
design claims, on a real (tiny) model:

  * **equivalence** — with per-request RNG and ``lr=0`` (bitwise-frozen
    params), every trajectory is a pure function of its request id, so
    a 2-worker fleet must reproduce the single-process
    ``ThreadedRuntime``'s trajectories exactly on the same seed —
    regardless of which worker generated which request, or where weight
    updates interrupted it (Prop. 1);
  * **kill** — SIGKILL one rollout worker mid-episode: the supervisor
    requeues its in-flight slots, respawns a replacement, and training
    completes with no trajectory lost or double-counted (DESIGN.md
    §Requeue semantics);
  * **throughput** — effective throughput of the 2-process fleet vs the
    threaded runtime on the same workload (a floor gate: process
    supervision + pipe transport must not collapse throughput; on
    multi-core hosts the GIL-free workers typically win).

One subprocess runs all three sections (2 fake host devices, hard
timeout — a fleet deadlock fails the lane fast instead of hanging it).
Results land in ``BENCH_fleet_overlap.json``; the gated metrics
(tools/check_bench.py) are ``equivalence.trajectories_identical``,
``kill.completed`` / ``kill.requeued`` / ``kill.duplicates`` /
``kill.lost`` and ``throughput_ratio``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import bench_path, emit, smoke_steps

DEVICES = 2
EQ_STEPS = 2            # equivalence window (B=4 each)
KILL_STEPS = 3
THR_STEPS = 4           # measured throughput window: wider than the Eq. 3
                        # budget (eta=2, B=4 -> <= 12 prebuffered), so the
                        # window always contains live generation
WARMUP_STEPS = 1        # excludes compile from the measured window
RUN_TIMEOUT = 600.0


def _cfg():
    from repro.configs.base import ModelConfig
    from repro.data import tokenizer
    return ModelConfig(name="bench-fleet", family="dense", n_layers=1,
                       d_model=32, n_heads=2, n_kv_heads=1, d_ff=64,
                       vocab_size=tokenizer.VOCAB_SIZE)


def _rl(lr: float = 0.0):
    from repro.configs.base import RLConfig
    return RLConfig(batch_size=4, answers_per_prompt=2, max_staleness=2,
                    interruptible=True, ppo_minibatches=1,
                    microbatch_token_budget=64, lr=lr,
                    max_prompt_len=16, max_gen_len=8)


# module-level so multiprocessing spawn can pickle them by reference
def engine_factory(*, seed: int = 0, n_slots: int = 2):
    from repro.core.fleet import build_engine
    return build_engine(model_cfg=_cfg(), seed=seed,
                        engine_kwargs=dict(n_slots=n_slots, prompt_len=16,
                                           max_gen_len=8, rng="request"))


def trainer_factory(*, seed: int = 0, lr: float = 0.0):
    from repro.core.fleet import build_trainer
    return build_trainer(model_cfg=_cfg(), rl=_rl(lr), seed=seed)


def _sched(lr: float = 0.0):
    from repro.core import AsyncScheduler
    from repro.env import EnvPromptStream, MathEnv
    return AsyncScheduler(
        prompt_stream=EnvPromptStream(MathEnv(seed=3, max_operand=9),
                                      answers_per_prompt=2),
        rl=_rl(lr), env=MathEnv(seed=3, max_operand=9))


def _capture(sched):
    cap = []
    orig = sched.record_consumed

    def wrapper(batch):
        cap.extend(batch)
        return orig(batch)

    sched.record_consumed = wrapper
    return cap


def _by_rid(cap):
    return {t.rid: (tuple(t.prompt_tokens), tuple(t.response_tokens))
            for t in cap}


def _fleet(sched, **kw):
    from repro.core import FleetRuntime
    defaults = dict(scheduler=sched, engine_factory=engine_factory,
                    engine_factory_kwargs={},
                    trainer_factory=trainer_factory,
                    trainer_factory_kwargs={}, n_slots=2, rollout_workers=2,
                    heartbeat_s=0.05, heartbeat_timeout=30.0)
    defaults.update(kw)
    return FleetRuntime(**defaults)


def _threaded(sched, lr: float = 0.0):
    from repro.core import ThreadedRuntime
    return ThreadedRuntime(engine=engine_factory(n_slots=4),
                           trainer=trainer_factory(lr=lr), scheduler=sched)


def _equivalence(steps: int):
    import time

    sched = _sched()
    ref_cap = _capture(sched)
    rt = _threaded(sched)
    rt.run(steps, timeout=RUN_TIMEOUT)
    ref = _by_rid(ref_cap)

    sched = _sched()
    cap = _capture(sched)
    frt = _fleet(sched)
    t0 = time.perf_counter()
    try:
        frt.run(steps, timeout=RUN_TIMEOUT)
    finally:
        frt.close()
    got = _by_rid(cap)
    common = sorted(set(ref) & set(got))
    return {
        "steps": steps,
        "n_reference": len(ref),
        "n_fleet": len(got),
        "n_common": len(common),
        "trajectories_identical": bool(
            common and all(ref[r] == got[r] for r in common)),
        "fleet_wall_s": round(time.perf_counter() - t0, 3),
    }


def _kill(steps: int):
    import signal
    import threading
    import time

    sched = _sched()
    cap = _capture(sched)
    rt = _fleet(sched)
    killed = {}

    def killer():
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            for h in rt.registry.ready("rollout"):
                if h.beats > 0 and rt.sched.inflight_of(h.worker_id):
                    killed["pid"] = h.proc.pid
                    os.kill(h.proc.pid, signal.SIGKILL)
                    return
            time.sleep(0.005)

    threading.Thread(target=killer, daemon=True).start()
    try:
        rt.run(steps, timeout=RUN_TIMEOUT)
    finally:
        rt.close()
    rids = [t.rid for t in cap]
    expected = steps * rt.rl.batch_size
    return {
        "steps": steps,
        "killed": bool(killed),
        "completed": bool(rt.version >= steps and killed),
        "requeued": rt.requeued,
        "respawns": rt.respawns,
        "duplicates": rt.duplicates_dropped + (len(rids) - len(set(rids))),
        "lost": expected - len(rids),
        "worker_dead_events": len(rt.registry.events_of("worker-dead")),
    }


def _throughput_one(kind: str, steps: int):
    import time

    sched = _sched(lr=1e-3)
    rt = _threaded(sched, lr=1e-3) if kind == "threaded" \
        else _fleet(sched, trainer_factory_kwargs={"lr": 1e-3})
    try:
        rt.run(WARMUP_STEPS, timeout=RUN_TIMEOUT)
        hist0 = len(rt.sched.history)
        t0 = time.perf_counter()
        rt.run(steps, timeout=RUN_TIMEOUT)
        wall = time.perf_counter() - t0
    finally:
        if kind == "fleet":
            rt.close()
    consumed = sum(h.n_tokens for h in rt.sched.history[hist0:])
    return {
        "versions": steps,
        "wall_s": round(wall, 3),
        "tokens_consumed": consumed,
        "effective_throughput_tok_s": round(consumed / wall, 2),
    }


def _child(eq_steps: int, kill_steps: int, thr_steps: int) -> None:
    import jax

    out = {"devices": len(jax.devices()),
           "equivalence": _equivalence(eq_steps),
           "kill": _kill(kill_steps),
           "threaded": _throughput_one("threaded", thr_steps),
           "fleet": _throughput_one("fleet", thr_steps)}
    print("BENCH_JSON=" + json.dumps(out), flush=True)


def main() -> None:
    eq_steps = smoke_steps(EQ_STEPS, 1)
    kill_steps = smoke_steps(KILL_STEPS, 2)
    thr_steps = smoke_steps(THR_STEPS, 1)
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.fleet_overlap", "--child",
         str(eq_steps), str(kill_steps), str(thr_steps)],
        capture_output=True, text=True, env=env, timeout=1800)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("BENCH_JSON=")][-1]
    rec = json.loads(line[len("BENCH_JSON="):])

    thr_fleet = rec["fleet"]["effective_throughput_tok_s"]
    thr_threaded = rec["threaded"]["effective_throughput_tok_s"]
    rec["throughput_ratio"] = round(thr_fleet / thr_threaded, 3) \
        if thr_threaded else None
    with open(bench_path("BENCH_fleet_overlap.json"), "w") as f:
        json.dump(rec, f, indent=2)

    us_per_version = (rec["fleet"]["wall_s"]
                      / max(rec["fleet"]["versions"], 1) * 1e6)
    emit("fleet_overlap_throughput", us_per_version,
         f"throughput_x{rec['throughput_ratio']:.2f}")
    emit("fleet_overlap_recovery",
         rec["kill"]["requeued"] * 1.0,
         f"identical_{rec['equivalence']['trajectories_identical']}"
         f"_lost_{rec['kill']['lost']}")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]))
    else:
        main()
