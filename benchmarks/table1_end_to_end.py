"""Table 1 analogue: end-to-end training hours, synchronous (colocated)
vs AReaL (disaggregated 75/25, interruptible, eta staleness) at equal
device count — via the calibrated discrete-event simulator.

Paper result: up to 2.77x end-to-end speedup (1.5B: 41.0h -> 14.8h).
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs.base import RLConfig
from repro.core import AsyncRLController
from repro.core.simulator import (HardwareModel, SimEngine, SimPromptStream,
                                  SimTrainer, WorkloadModel, make_llm_timing)

# (name, params, devices(=8*nodes), eta, mean response len)
SETTINGS = [
    ("1.5b_math_16nodes", 1.5e9, 128, 8, 6000),
    ("7b_math_24nodes", 7e9, 192, 8, 8000),
    ("14b_code_32nodes", 14e9, 256, 4, 8000),
    ("32b_code_48nodes", 32e9, 384, 4, 10000),
]
STEPS = 8               # simulated PPO steps (paper: 250/80; linear scale-up)
BATCH = 512
MAX_LEN = 28_672


def _run(n_params, devices, eta, mean_len, *, colocated, steps=STEPS, seed=0):
    hw = HardwareModel()
    wl = WorkloadModel(n_params=n_params)
    if colocated:
        timing = make_llm_timing(hw, wl, n_gen_devices=devices,
                                 n_train_devices=devices, colocated=True)
        rl = RLConfig(batch_size=BATCH, max_staleness=0, interruptible=False)
    else:
        ng = int(devices * 0.75)
        timing = make_llm_timing(hw, wl, n_gen_devices=ng,
                                 n_train_devices=devices - ng)
        rl = RLConfig(batch_size=BATCH, max_staleness=eta, interruptible=True)
    eng = SimEngine(n_slots=4 * BATCH, mean_len=mean_len, max_len=MAX_LEN,
                    prompt_len=1024, seed=seed)
    ctl = AsyncRLController(engine=eng, trainer=SimTrainer(),
                            prompt_stream=SimPromptStream(1024), rl=rl,
                            timing=timing)
    hist = ctl.run(steps)
    return hist[-1].clock, ctl


def main():
    for name, n, dev, eta, mlen in SETTINGS:
        with timed() as t1:
            t_sync, _ = _run(n, dev, eta, mlen, colocated=True)
        with timed() as t2:
            t_async, ctl = _run(n, dev, eta, mlen, colocated=False)
        speedup = t_sync / t_async
        emit(f"table1_{name}_sync_hours", 1e6 * t1["s"] / STEPS,
             f"{t_sync / 3600:.2f}h_per_{STEPS}steps")
        emit(f"table1_{name}_areal_hours", 1e6 * t2["s"] / STEPS,
             f"{t_async / 3600:.2f}h_per_{STEPS}steps")
        emit(f"table1_{name}_speedup", 1e6 * (t1["s"] + t2["s"]) / STEPS,
             f"{speedup:.2f}x")


if __name__ == "__main__":
    main()
