"""Serving gateway under Poisson load with Zipf session sharing
(DESIGN.md §Serving gateway, §Prefix eviction policy).

Three sections, all on the deterministic tick clock (one gateway pump =
one tick), so every number below is a pure function of the seeded
schedule and the engine seed — the TTFT/ITL percentiles are held at
ZERO drift by the regression gate and the schedule is identical in
smoke and full runs:

  * ``baseline`` — an adequately sized paged pool with LRU parking.
    Arrivals are Poisson (seeded exponential inter-arrival ticks);
    sessions are drawn Zipf-style from ~1M logical session ids (rank =
    floor(N^u): rank 1 is hottest), and each request's own tokens come
    from a small template set, so hot sessions and shared templates
    both exercise the chained-prefix cache.  Records p50/p99 TTFT and
    inter-token latency in ticks, the prefix-hit rate (reused blocks /
    shareable full prompt blocks at admission), and the LRU
    eviction/revival/recompute counters.
  * ``pressure`` — the same trace against a pool too small to hold the
    working set: ``alloc`` must evict parked prefixes and admission
    must defer-and-retry.  The gated claims: evictions actually
    happened AND ``deferred_permanent`` (submitted - completed after
    drain) is ZERO — LRU degrades pool exhaustion to recompute, never
    to a wedged request.
  * ``recompute`` — a session-less shared-prefix trace run twice, on an
    undersized pool (evictions force recompute-on-miss) and on an
    ample one.  Per-request token sequences must be identical: a
    recomputed prefix reproduces the original KV exactly, and the
    per-request RNG stream makes each trajectory a pure function of
    (seed, rid) regardless of scheduling.

Wall-clock throughput is also reported (per-section) for eyeballing;
only the deterministic tick metrics are banded by tools/check_bench.py.
Results land in ``BENCH_serve_gateway.json`` via ``bench_path``.
"""
from __future__ import annotations

import json
import math
import random
import time

from benchmarks.common import bench_path, emit

N_SLOTS = 4
PROMPT_LEN = 12
MAX_GEN = 6
BLOCK_SIZE = 4
N_LOGICAL_SESSIONS = 1_000_000
N_REQUESTS = 40
ARRIVAL_MEAN_TICKS = 2.0       # Poisson rate: 1 request / 2 ticks
PRESSURE_BLOCKS = 14           # < N_SLOTS * ceil(max_len / bs) = 20
RECOMPUTE_BLOCKS = 10          # cold trace: parked prefixes MUST evict
AMPLE_BLOCKS = 96
TEMPLATES = [[1, 4, 5, 6, 20 + t, 21, 22, 23] for t in range(4)]


def _build(n_blocks, seed=0):
    import jax

    from repro.configs.base import ModelConfig
    from repro.core.config import EngineConfig
    from repro.core.rollout import RolloutEngine
    from repro.data import tokenizer
    from repro.models.model import build_model
    from repro.serve import Gateway

    cfg = ModelConfig(name="bench-gw", family="dense", n_layers=2,
                      d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
                      vocab_size=tokenizer.VOCAB_SIZE)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(seed))
    eng = RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=N_SLOTS, prompt_len=PROMPT_LEN, max_gen_len=MAX_GEN,
        seed=seed, cache="paged", block_size=BLOCK_SIZE, n_blocks=n_blocks,
        evict="lru", prefill_chunk=BLOCK_SIZE))
    return Gateway(eng, preempt=False)


def _zipf_rank(rng: random.Random, n: int) -> int:
    """Zipf-ish rank in [1, n]: P(rank <= k) ~ log k / log n, so rank 1
    is drawn far more often than rank 1e6 — the hot-session skew."""
    return int(n ** rng.random())


def _schedule(n_requests, *, sessions=True, seed=1234):
    """The seeded arrival trace: (arrival_tick, tokens, session) rows.
    Poisson arrivals via exponential inter-arrival ticks."""
    rng = random.Random(seed)
    t = 0.0
    rows = []
    for i in range(n_requests):
        t += rng.expovariate(1.0 / ARRIVAL_MEAN_TICKS)
        tmpl = TEMPLATES[_zipf_rank(rng, len(TEMPLATES) ** 3)
                         % len(TEMPLATES)]
        sess = (f"s{_zipf_rank(rng, N_LOGICAL_SESSIONS)}"
                if sessions else None)
        rows.append((int(t), list(tmpl), sess))
    return rows


def _drive(gw, rows):
    """Feed the trace at its arrival ticks; drain; return rid list."""
    idx, rids, guard = 0, [], 0
    while idx < len(rows) or gw.has_work():
        now = gw.now()
        while idx < len(rows) and rows[idx][0] <= now:
            _, toks, sess = rows[idx]
            rids.append(gw.submit(toks, session=sess))
            idx += 1
        gw.pump()
        guard += 1
        assert guard < 100_000, "gateway trace did not drain"
    return rids


def _run_section(n_blocks, rows):
    gw = _build(n_blocks)
    t0 = time.perf_counter()
    rids = _drive(gw, rows)
    wall = time.perf_counter() - t0
    out = {r: tuple(gw.drain(r)["tokens"]) for r in rids}
    st = gw.stats()
    tokens = sum(len(v) for v in out.values())
    return out, {
        "n_blocks": n_blocks,
        "submitted": len(rows),
        "completed": st["completed"],
        "deferred_permanent": len(rows) - st["completed"],
        "prefix_hit_rate": st["prefix_hit_rate"],
        "prefix_reused_blocks": st["prefix_reused_blocks"],
        "session_hits": st["session_hits"],
        "evictions": st["evictions"],
        "revivals": st["revivals"],
        "deferred_retries": st["deferred"],
        "recompute_tokens": st["recompute_tokens"],
        "ttft_p50": st["ttft_p50"],
        "ttft_p99": st["ttft_p99"],
        "itl_p50": st["itl_p50"],
        "itl_p99": st["itl_p99"],
        "ticks": st["ticks"],
        "wall_s": round(wall, 4),
        "throughput_tok_s": round(tokens / max(wall, 1e-9), 2),
    }


def main() -> None:
    # the trace is deliberately NOT reduced in smoke mode: every banded
    # metric is tick-deterministic, so smoke must reproduce the
    # committed numbers exactly (same discipline as the weight-stream
    # stall section)
    trace = _schedule(N_REQUESTS, sessions=True)
    cold = _schedule(max(12, N_REQUESTS // 3), sessions=False, seed=77)

    _run_section(AMPLE_BLOCKS, trace)          # warmup: compiles every sig
    _, baseline = _run_section(AMPLE_BLOCKS, trace)
    _, pressure = _run_section(PRESSURE_BLOCKS, trace)
    small_out, small = _run_section(RECOMPUTE_BLOCKS, cold)
    ample_out, _ = _run_section(AMPLE_BLOCKS, cold)
    identical = small_out == ample_out
    assert identical, "recompute-on-miss altered a trajectory"
    assert small["evictions"] > 0, \
        "recompute section never evicted: identity claim is vacuous"
    assert pressure["deferred_permanent"] == 0, \
        "undersized pool permanently wedged a request"
    assert pressure["evictions"] > 0, \
        "pressure section did not actually evict"

    record = {
        "config": {"n_slots": N_SLOTS, "prompt_len": PROMPT_LEN,
                   "max_gen_len": MAX_GEN, "block_size": BLOCK_SIZE,
                   "n_requests": N_REQUESTS,
                   "arrival_mean_ticks": ARRIVAL_MEAN_TICKS,
                   "logical_sessions": N_LOGICAL_SESSIONS,
                   "pressure_blocks": PRESSURE_BLOCKS,
                   "recompute_blocks": RECOMPUTE_BLOCKS,
                   "ample_blocks": AMPLE_BLOCKS},
        "baseline": baseline,
        "pressure": pressure,
        "recompute": {
            "trajectories_identical": identical,
            "n_common": len(small_out),
            "small_evictions": small["evictions"],
            "small_recompute_tokens": small["recompute_tokens"],
        },
    }
    with open(bench_path("BENCH_serve_gateway.json"), "w") as f:
        json.dump(record, f, indent=2)

    per_tok = baseline["wall_s"] / max(baseline["completed"] * MAX_GEN, 1)
    emit("serve_gateway_ttft", baseline["ttft_p99"],
         f"hit{baseline['prefix_hit_rate']:.2f}")
    emit("serve_gateway_pressure", per_tok * 1e6,
         f"evict{pressure['evictions']}")


if __name__ == "__main__":
    # no smoke_steps use, but keep the import surface honest
    assert math.isfinite(ARRIVAL_MEAN_TICKS)
    main()
