"""Reward-verification overlap: async scoring keeps throughput flat
while synchronous scoring degrades with verifier latency.

AReaL's reward service is the fourth system component (Section 4.1):
its verification latency is pipelined behind generation.  This
benchmark injects a controlled verifier latency (``DelayEnv``) into the
threaded runtime and measures effective throughput two ways over the
same fixed window:

  * **sync**  — ``reward_workers = 0``: every finished trajectory is
    verified inline on the rollout thread (the scheduler's synchronous
    environment path), so each verification stalls every decoding slot
    for the full injected latency;
  * **async** — an ``AsyncRewardService`` pool scores off the rollout
    thread (DESIGN.md §Environments and reward service): collection is
    enqueue-only and verification overlaps decoding, so throughput
    stays ~flat at the same injected latency.

Both runs execute in one subprocess with 4 fake host devices (the real
disaggregated submesh split), 2 warm-up versions excluded (first
weight-pickup compiles the full-width re-prefill, see
benchmarks/async_overlap.py), and identical seeds/workloads.

A second section drives the CODE environment end-to-end on the same
tiny pipeline — generated text executed against unit tests in the
restricted subprocess sandbox — so the sandbox runs in CI smoke
(``code_env.completed``).

Results land in ``BENCH_reward_overlap.json``; the gated metrics
(tools/check_bench.py) are ``throughput_ratio`` (async/sync >= 1.5x at
the injected latency), ``async.backlog_bounded`` and
``code_env.completed``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import bench_path, emit

DEVICES = 4
STEPS = 4               # measured versions (fixed window, both modes)
WARMUP_STEPS = 2        # excludes first-compile incl. active-slot re-prefill
LATENCY_S = 0.08        # injected verification latency per trajectory
WORKERS = 4
BACKLOG = 64


def _build(mode: str, seed: int = 0):
    """The async_overlap tiny balanced pipeline, with scoring routed
    through a DelayEnv-wrapped math environment: inline (sync) or via
    the reward-worker pool (async)."""
    import jax

    from repro.configs.base import ModelConfig, RLConfig
    from repro.core import (AsyncScheduler, EngineConfig, PPOTrainer,
                            RolloutEngine, ThreadedRuntime)
    from repro.data import tokenizer
    from repro.env import (AsyncRewardService, DelayEnv, EnvPromptStream,
                           MathEnv)
    from repro.launch.train import _place_disaggregated
    from repro.models.model import build_model

    cfg = ModelConfig(name="bench-reward", family="dense", n_layers=2,
                      d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
                      vocab_size=tokenizer.VOCAB_SIZE)
    rl = RLConfig(batch_size=16, answers_per_prompt=4, max_staleness=4,
                  interruptible=True, ppo_minibatches=2,
                  microbatch_token_budget=128, lr=1e-3,
                  max_prompt_len=16, max_gen_len=16)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(seed))
    engine = RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=8, prompt_len=16, max_gen_len=16, seed=seed))
    trainer = PPOTrainer(model, rl, params)
    env = DelayEnv(MathEnv(seed=seed, max_operand=9), LATENCY_S)
    service = None
    if mode == "async":
        service = AsyncRewardService(env, n_workers=WORKERS,
                                     max_backlog=BACKLOG)
    sched = AsyncScheduler(prompt_stream=EnvPromptStream(env, 4), rl=rl,
                           env=env, reward_service=service)
    roll_mesh = None
    if len(jax.devices()) > 1:
        roll_mesh, _ = _place_disaggregated(engine, trainer, 0.25)
    rt = ThreadedRuntime(engine=engine, trainer=trainer, scheduler=sched,
                         rollout_mesh=roll_mesh)
    return rt, service


def _measure(mode: str, steps: int, seed: int = 0):
    import time

    rt, service = _build(mode, seed)
    rt.run(WARMUP_STEPS, timeout=600)        # compiles outside the window
    v0 = rt.trainer.version
    hist0 = len(rt.history)
    t0 = time.perf_counter()
    rt.run(steps, timeout=600)
    wall = time.perf_counter() - t0
    consumed = sum(h.n_tokens for h in rt.history[hist0:])
    rec = {
        "mode": mode,
        "versions": rt.trainer.version - v0,
        "wall_s": round(wall, 3),
        "tokens_consumed": consumed,
        "effective_throughput_tok_s": round(consumed / wall, 2),
        "unscored_at_end": rt.sched.pending_rewards(),
    }
    if service is not None:
        st = service.stats()
        rec["reward_workers"] = st["n_workers"]
        rec["n_scored"] = st["n_scored"]
        rec["backlog_peak"] = st["backlog_peak"]
        rec["verify_mean_s"] = round(
            st["per_env"]["delay(math)"]["mean_s"], 4)
        # bounded backlog: admission backpressure caps unscored work at
        # max_backlog plus the generations already in flight (slots)
        rec["backlog_bounded"] = (st["backlog_peak"]
                                  <= st["max_backlog"] + rt.engine.n_slots)
        assert service.close(), "reward workers failed to drain"
    return rec


def _code_env(seed: int = 0):
    """Drive the CODE environment through the same stack: one PPO
    version whose every trajectory was verified by the subprocess
    sandbox on reward workers (the CI-smoke sandbox exercise)."""
    import jax

    from repro.configs.base import ModelConfig, RLConfig
    from repro.core import (AsyncScheduler, EngineConfig, PPOTrainer,
                            RolloutEngine, ThreadedRuntime)
    from repro.data import tokenizer
    from repro.env import AsyncRewardService, CodeEnv, EnvPromptStream

    from repro.models.model import build_model

    cfg = ModelConfig(name="bench-code", family="dense", n_layers=2,
                      d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
                      vocab_size=tokenizer.VOCAB_SIZE)
    rl = RLConfig(batch_size=8, answers_per_prompt=2, max_staleness=4,
                  interruptible=True, ppo_minibatches=2,
                  microbatch_token_budget=128, lr=1e-3,
                  max_prompt_len=56, max_gen_len=12)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(seed))
    engine = RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=4, prompt_len=56, max_gen_len=12, seed=seed))
    env = CodeEnv(seed=seed, timeout_s=2.0)
    service = AsyncRewardService(env, n_workers=2, max_backlog=16)
    sched = AsyncScheduler(prompt_stream=EnvPromptStream(env, 2), rl=rl,
                           reward_service=service)
    rt = ThreadedRuntime(engine=engine, trainer=PPOTrainer(model, rl, params),
                         scheduler=sched)
    rt.run(1, timeout=600)
    st = service.stats()
    drained = service.close()
    scored = st["n_scored"]
    return {
        "completed": bool(rt.trainer.version >= 1 and drained
                          and len(rt.history) >= 1),
        "scored": scored,
        "sandbox_verifications": st["per_env"].get("code", {}).get("n", 0),
        "verify_mean_s": round(
            st["per_env"].get("code", {}).get("mean_s", 0.0), 4),
        "accuracy": rt.reward.accuracy,
    }


def _child(steps: int) -> None:
    import jax

    out = {"devices": len(jax.devices()), "steps": steps,
           "injected_latency_s": LATENCY_S,
           "sync": _measure("sync", steps),
           "async": _measure("async", steps),
           "code_env": _code_env()}
    print("BENCH_JSON=" + json.dumps(out), flush=True)


def main() -> None:
    steps = STEPS
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={DEVICES}"
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.reward_overlap",
         "--child", str(steps)],
        capture_output=True, text=True, env=env, timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("BENCH_JSON=")][-1]
    rec = json.loads(line[len("BENCH_JSON="):])

    thr_async = rec["async"]["effective_throughput_tok_s"]
    thr_sync = rec["sync"]["effective_throughput_tok_s"]
    rec["throughput_ratio"] = round(thr_async / thr_sync, 3) if thr_sync \
        else None
    with open(bench_path("BENCH_reward_overlap.json"), "w") as f:
        json.dump(rec, f, indent=2)

    us_per_version = (rec["async"]["wall_s"]
                      / max(rec["async"]["versions"], 1) * 1e6)
    emit("reward_overlap_async", us_per_version,
         f"throughput_x{rec['throughput_ratio']:.2f}")
    emit("reward_overlap_code_env",
         rec["code_env"]["verify_mean_s"] * 1e6,
         f"sandbox_n_{rec['code_env']['sandbox_verifications']}")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]))
    else:
        main()
