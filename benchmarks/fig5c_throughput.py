"""Figure 5c / Appendix C.3 analogue: effective training throughput vs
max staleness eta (the staleness-throughput trade-off).

Paper result (8 GPUs, 1.5B, Table 7): 27.1k tok/s at eta=0 rising to
~52k at eta>=8 — throughput saturates once generation fully hides
behind training.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.configs.base import RLConfig
from repro.core import AsyncRLController
from repro.core.simulator import (HardwareModel, SimEngine, SimPromptStream,
                                  SimTrainer, WorkloadModel, make_llm_timing)

STEPS = 6


def main():
    hw = HardwareModel()
    wl = WorkloadModel(n_params=1.5e9)
    base = None
    for eta in (0, 1, 2, 4, 8, 16):
        timing = make_llm_timing(hw, wl, n_gen_devices=6, n_train_devices=2)
        rl = RLConfig(batch_size=64 * 16, max_staleness=eta,
                      interruptible=True)
        eng = SimEngine(n_slots=2048, mean_len=2000, max_len=7168,
                        prompt_len=1024, seed=0)
        ctl = AsyncRLController(engine=eng, trainer=SimTrainer(),
                                prompt_stream=SimPromptStream(1024), rl=rl,
                                timing=timing)
        with timed() as t:
            ctl.run(STEPS)
        thr = ctl.effective_throughput()
        base = base or thr
        emit(f"fig5c_eta{eta}", 1e6 * t["s"] / STEPS,
             f"{thr:.0f}tok/s;x{thr / base:.2f}_vs_eta0")


if __name__ == "__main__":
    main()
