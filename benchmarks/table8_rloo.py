"""Appendix C.4 / Table 8 analogue: staleness tolerance of the RLOO
estimator vs PPO/GRPO — REAL tiny-model runs.

Paper finding: RLOO "exhibits slightly better tolerance to asynchronous
training compared to vanilla PPO"; throughput is estimator-independent.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, timed
from repro.launch.train import run_training

STEPS = int(os.environ.get("BENCH_RLOO_STEPS", "15"))


def main():
    for adv in ("grpo", "rloo"):
        for eta in (0, 4):
            with timed() as t:
                ctl, trainer, reward = run_training(
                    steps=STEPS, eta=eta, adv_estimator=adv,
                    batch_size=16, answers_per_prompt=4, n_slots=64,
                    max_operand=5, lr=1e-3, log_every=10**9, seed=2)
            tail = ctl.history[-3:]
            emit(f"table8_{adv}_eta{eta}", 1e6 * t["s"] / STEPS,
                 f"acc={np.mean([h.accuracy for h in tail]):.3f};"
                 f"reward={np.mean([h.reward_mean for h in tail]):+.2f};"
                 f"thr={ctl.effective_throughput():.0f}tok/s")


if __name__ == "__main__":
    main()
