"""Benchmark-regression gate (the CI bench lane's last step).

Compares candidate BENCH_*.json files — produced by
``python -m benchmarks.run --smoke`` with ``REPRO_BENCH_OUT`` pointing at
a scratch dir (see ``benchmarks/common.bench_path``) — against the
committed baselines in the repo root, with a per-metric tolerance band.

Two kinds of checks, chosen per metric:

* **absolute floors/ceilings** (``min``/``max``/``equals``) for metrics
  that are structural claims of the system — the paged engine's
  slots-at-fixed-HBM ratio, the chunked engine's stall reduction, the
  threaded runtime demonstrating true overlap.  These hold in smoke mode
  and on noisy 2-core CI runners, so the bands are deliberately looser
  than the committed full-run numbers (a smoke run must not fail the
  gate for being small, only for REGRESSING).
* **baseline-relative bands** (``rel``) for metrics that are
  deterministic functions of the workload (allocator math), where smoke
  equals the full run and any drift is a real behavior change.

A missing candidate file, a missing metric, or a band violation fails
the gate (exit 1, one line per violation).  Stdlib only.

    python tools/check_bench.py --candidate /tmp/repro-bench [--baseline .]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Any, Dict, List


def get_path(obj: Any, path: str) -> Any:
    """Resolve 'a.b[2].c' style metric paths."""
    cur = obj
    for part in path.replace("]", "").replace("[", ".").split("."):
        if part == "":
            continue
        if isinstance(cur, list):
            cur = cur[int(part)]
        else:
            cur = cur[part]
    return cur


# file -> list of metric specs.  Keys per spec:
#   path            dotted path into the candidate JSON
#   min / max       absolute band (structural floor/ceiling)
#   equals          exact expected value
#   rel             allowed |candidate - baseline| / |baseline| (compared
#                   against the committed baseline's value at `path`)
SPECS: Dict[str, List[Dict[str, Any]]] = {
    "BENCH_paged_cache.json": [
        # PR 2 acceptance: >= 2x concurrent slots at fixed HBM.
        {"path": "min_slots_ratio", "min": 2.0},
        # allocator math is deterministic and step-count independent:
        # smoke must reproduce the committed curve exactly (small float
        # slack for the ratio rounding).
        {"path": "curve[0].paged_slots", "rel": 0.0},
        {"path": "curve[5].paged_slots", "rel": 0.0},
    ],
    "BENCH_chunked_prefill.json": [
        # This PR's acceptance: >= 2x smaller max decode-stall...
        {"path": "stall_reduction_x", "min": 2.0},
        # ... at (loosely) equal throughput; chunked is usually FASTER
        # (it skips the padded full-width re-prefill) so only a floor.
        {"path": "throughput_ratio", "min": 0.7},
        # identity property: both modes sampled the SAME token sequences
        # (the benchmark compares full per-request responses, not counts)
        {"path": "trajectories_identical", "equals": True},
        {"path": "chunked.tokens", "rel": 0.0,
         "baseline_path": "monolithic.tokens", "same_file": "candidate"},
    ],
    "BENCH_async_overlap.json": [
        # threaded must not be SLOWER than forced-serial, even on noisy
        # 2-core runners (committed full-run number is ~1.66x).
        {"path": "throughput_ratio", "min": 1.0},
        {"path": "overlap_demonstrated", "equals": True},
        # ISSUE 10 acceptance: the traced re-run emits a timeline that
        # tools/trace_check.py validates, with at least one wall-clock-
        # concurrent rollout/trainer span pair (>100 on the committed
        # run — the overlap is visible in the artifact, not just the
        # throughput ratio).
        {"path": "trace.valid", "equals": True},
        {"path": "trace.concurrent_span_pairs", "min": 1},
    ],
    "BENCH_trace_overhead.json": [
        # ISSUE 10 acceptance: tracing-enabled serving throughput stays
        # within 5% of tracing-disabled on the identical tick-
        # deterministic workload (best-of-reps per mode).
        {"path": "throughput_ratio", "min": 0.95},
        # the traced mode really traced (a zero here means the gate
        # above compared two untraced runs)
        {"path": "traced.events_per_rep", "min": 1},
    ],
    "BENCH_reward_overlap.json": [
        # PR 5 acceptance: at the injected verifier latency, async
        # scoring (reward workers) sustains >= 1.5x the synchronous
        # inline-verification throughput — verification is pipelined
        # behind generation, not serialized into it.
        {"path": "throughput_ratio", "min": 1.5},
        # admission backpressure keeps the unscored backlog bounded
        {"path": "async.backlog_bounded", "equals": True},
        # the code-environment sandbox actually ran (CI smoke exercises
        # subprocess verification end-to-end)
        {"path": "code_env.completed", "equals": True},
        {"path": "code_env.sandbox_verifications", "min": 1},
    ],
    "BENCH_fleet_overlap.json": [
        # PR 6 acceptance: the 2-worker process fleet reproduces the
        # single-process ThreadedRuntime's trajectories bit-for-bit on
        # the same seed (per-request RNG + lr=0 frozen params).
        {"path": "equivalence.trajectories_identical", "equals": True},
        {"path": "equivalence.n_common", "min": 1},
        # a SIGKILLed worker's in-flight slots are requeued and training
        # completes with nothing lost or double-counted.
        {"path": "kill.completed", "equals": True},
        {"path": "kill.requeued", "min": 1},
        {"path": "kill.duplicates", "equals": 0},
        {"path": "kill.lost", "equals": 0},
        # floor only: process supervision + pipe transport must not
        # collapse throughput vs the threaded runtime (fleet pipelining
        # usually puts this well above 1 on multi-core hosts).
        {"path": "throughput_ratio", "min": 0.2},
    ],
    "BENCH_decode_speed.json": [
        # PR 8 acceptance: the fused single-dispatch decode step is
        # bit-identical to the default and split paths and at least as
        # fast as the two-dispatch split baseline (best-of-repeats).
        {"path": "fused.trajectories_identical", "equals": True},
        {"path": "fused.throughput_ratio", "min": 1.0},
        {"path": "fused.dispatches_per_step", "equals": 1.0},
        # greedy self-speculative decoding reproduces the plain greedy
        # engine's full token sequences, and under controlled 100%
        # draft acceptance commits strictly more than one token per
        # member-dispatch (1.0 = plain decode; k=4 full acceptance
        # would be 2.0, EOS/headroom truncation pulls it slightly down)
        {"path": "spec.trajectories_identical", "equals": True},
        {"path": "spec.accepted_tokens_per_step", "min": 1.05},
        {"path": "spec.draft_acceptance_rate", "min": 0.5},
        # per-family decode throughput sits inside its memory-bound
        # roofline: gap in (0, 1] for every architecture family
        {"path": "families.transformer.measured_over_roofline",
         "min": 1e-9, "max": 1.0},
        {"path": "families.rg-lru.measured_over_roofline",
         "min": 1e-9, "max": 1.0},
        {"path": "families.xlstm.measured_over_roofline",
         "min": 1e-9, "max": 1.0},
    ],
    "BENCH_serve_gateway.json": [
        # PR 9 acceptance: the Zipf session trace actually shares its
        # chained prompt prefixes through the paged pool (floor is far
        # below the committed ~0.9 so template tweaks don't flap it).
        {"path": "baseline.prefix_hit_rate", "min": 0.3},
        # the undersized-pool section must genuinely thrash the LRU ...
        {"path": "pressure.evictions", "min": 1},
        # ... and STILL complete everything: pool exhaustion degrades to
        # recompute, never to a permanently deferred request.
        {"path": "pressure.deferred_permanent", "equals": 0},
        {"path": "pressure.completed", "rel": 0.0},
        # recompute-on-miss is bit-exact, and the claim is non-vacuous
        # (the small pool really evicted prefixes that were re-requested)
        {"path": "recompute.trajectories_identical", "equals": True},
        {"path": "recompute.small_evictions", "min": 1},
        # the whole trace runs on the deterministic tick clock: latency
        # percentiles are held at zero drift vs the committed baseline
        {"path": "baseline.ttft_p50", "rel": 0.0},
        {"path": "baseline.ttft_p99", "rel": 0.0},
        {"path": "baseline.itl_p50", "rel": 0.0},
        {"path": "baseline.itl_p99", "rel": 0.0},
    ],
    "BENCH_weight_stream.json": [
        # PR 7 acceptance: unquantized streaming is bit-for-bit
        # trajectory-identical to a monolithic full-tree update at the
        # same step boundary, across ring/paged x monolithic/chunked.
        {"path": "identity.all_identical", "equals": True},
        {"path": "identity.ring_monolithic.n_finished", "min": 4},
        # tokens lost per update drop >= 2x under the fixed 1-chunk-per-
        # opportunity transport model, at no throughput cost; the whole
        # stall section is schedule-deterministic and single-threaded,
        # so its numbers are held at ZERO drift vs the committed run
        # (step counts are fixed even in smoke mode).
        {"path": "stall.tokens_lost_ratio", "min": 2.0},
        {"path": "stall.throughput_ratio", "min": 1.0},
        {"path": "stall.tokens_lost_delta_per_update", "equals": 0.0},
        {"path": "stall.tokens_lost_full_per_update", "rel": 0.0},
        {"path": "stall.chunks_full_per_update", "rel": 0.0},
        {"path": "stall.chunks_delta_per_update", "rel": 0.0},
        # publication-to-pickup latency (decode opportunities): zero
        # drift, and streamed pickup strictly inside the full transfer
        {"path": "stall.delta_latency_steps", "rel": 0.0},
        {"path": "stall.full_latency_steps", "rel": 0.0},
        # delta-q decodes within its own declared per-chunk tolerance
        # and IS lossy (the exact-XOR path is the identity section)
        {"path": "quantized.within_tolerance", "equals": True},
        {"path": "quantized.lossy", "equals": True},
        # the real executors: threaded full vs delta identical on lr=0,
        # and a worker SIGKILLed mid-stream leaves a fleet that finishes
        # with nothing lost/duplicated and bit-identical trajectories
        # (proof the torn partial version was never applied)
        {"path": "threaded.trajectories_identical", "equals": True},
        {"path": "threaded.streams_completed", "min": 1},
        # (requeue-on-kill >= 1 is gated by BENCH_fleet_overlap.json;
        # here the kill lands mid-stream, where the victim may have
        # already delivered everything it owed — the mid-stream-specific
        # invariant is that NO torn partial version is ever applied,
        # i.e. trajectories stay bit-identical.)
        {"path": "fleet_kill.killed", "equals": True},
        {"path": "fleet_kill.completed", "equals": True},
        {"path": "fleet_kill.duplicates", "equals": 0},
        {"path": "fleet_kill.lost", "equals": 0},
        {"path": "fleet_kill.trajectories_identical", "equals": True},
    ],
}


def check_file(name: str, specs: List[Dict[str, Any]], candidate_dir: Path,
               baseline_dir: Path, errors: List[str]) -> None:
    cpath = candidate_dir / name
    bpath = baseline_dir / name
    if not cpath.exists():
        errors.append(f"{name}: candidate missing ({cpath})")
        return
    if not bpath.exists():
        errors.append(f"{name}: committed baseline missing ({bpath})")
        return
    cand = json.loads(cpath.read_text())
    base = json.loads(bpath.read_text())
    for spec in specs:
        path = spec["path"]
        try:
            val = get_path(cand, path)
        except (KeyError, IndexError, TypeError):
            errors.append(f"{name}: metric '{path}' missing from candidate")
            continue
        if "equals" in spec and val != spec["equals"]:
            errors.append(f"{name}: {path} = {val!r}, expected "
                          f"{spec['equals']!r}")
        if "min" in spec and not (isinstance(val, (int, float))
                                  and val >= spec["min"]):
            errors.append(f"{name}: {path} = {val!r} below floor "
                          f"{spec['min']}")
        if "max" in spec and not (isinstance(val, (int, float))
                                  and val <= spec["max"]):
            errors.append(f"{name}: {path} = {val!r} above ceiling "
                          f"{spec['max']}")
        if "rel" in spec:
            ref_obj = cand if spec.get("same_file") == "candidate" else base
            ref_path = spec.get("baseline_path", path)
            try:
                ref = get_path(ref_obj, ref_path)
            except (KeyError, IndexError, TypeError):
                errors.append(f"{name}: reference metric '{ref_path}' missing")
                continue
            denom = max(abs(float(ref)), 1e-12)
            drift = abs(float(val) - float(ref)) / denom
            if drift > spec["rel"] + 1e-12:
                errors.append(
                    f"{name}: {path} = {val!r} drifted {drift:.3%} from "
                    f"{ref!r} (allowed {spec['rel']:.3%})")


def run(candidate_dir: Path, baseline_dir: Path,
        specs: Dict[str, List[Dict[str, Any]]] = SPECS) -> List[str]:
    errors: List[str] = []
    for name, file_specs in specs.items():
        check_file(name, file_specs, candidate_dir, baseline_dir, errors)
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--candidate", required=True,
                    help="dir holding the smoke run's BENCH_*.json "
                         "(the REPRO_BENCH_OUT scratch dir)")
    ap.add_argument("--baseline", default=".",
                    help="dir holding the committed baselines (repo root)")
    args = ap.parse_args(argv)
    errors = run(Path(args.candidate), Path(args.baseline))
    for e in errors:
        print(f"BENCH REGRESSION: {e}", file=sys.stderr)
    if not errors:
        n = sum(len(v) for v in SPECS.values())
        print(f"check_bench: {n} metric bands over {len(SPECS)} files OK")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
