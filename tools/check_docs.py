"""Docs consistency checker (the CI docs lane).

Two guarantees:

1. Every ``DESIGN.md §<section>`` reference in a Python source file
   resolves to a heading of DESIGN.md.  Docstrings cite sections by
   name; this is what keeps those citations from rotting (the original
   sin this tool exists to prevent: code shipping with references to a
   DESIGN.md that didn't exist).
2. Relative markdown links in the documentation set (README.md,
   DESIGN.md, docs/OPERATIONS.md, benchmarks/README.md) point at files
   that exist, and ``#anchor`` fragments match a heading (GitHub slug
   rules) in the target document.
3. No dead design sections: every H2/H3 heading of DESIGN.md is cited
   by at least one ``DESIGN.md §<section>`` reference somewhere in the
   source tree.  A section nobody cites is either documentation that
   rotted away from the code or code that shipped without claiming its
   design — both are failures.

Exit status is non-zero with one line per violation.  Stdlib only — the
CI docs lane runs it without installing the package.

    python tools/check_docs.py
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SOURCE_DIRS = ("src", "tests", "benchmarks", "examples", "tools")
DOC_FILES = ("README.md", "DESIGN.md", "docs/OPERATIONS.md",
             "benchmarks/README.md")

# a section citation: the filename, '§', then a name running until a
# character that can't be part of a heading (citations close with ')',
# ':', '.', etc.)
SECTION_REF = re.compile(r"DESIGN\.md\s+§\s*([A-Za-z0-9][A-Za-z0-9 -]*)")
HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def leveled_headings_of(md_path: Path):
    """(level, text) heading pairs of a markdown file (code fences
    excluded)."""
    out = []
    fenced = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if fenced:
            continue
        m = HEADING.match(line)
        if m:
            out.append((len(m.group(1)), m.group(2)))
    return out


def headings_of(md_path: Path):
    """Heading texts of a markdown file (code fences excluded)."""
    return [h for _, h in leveled_headings_of(md_path)]


def all_section_refs():
    """Every ``DESIGN.md §<section>`` citation in the source tree, as
    (source file, cited name) pairs (docstrings wrap, so whitespace is
    collapsed before matching)."""
    refs = []
    for d in SOURCE_DIRS:
        for py in sorted((ROOT / d).rglob("*.py")):
            text = re.sub(r"\s+", " ", py.read_text(encoding="utf-8"))
            for m in SECTION_REF.finditer(text):
                refs.append((py.relative_to(ROOT), m.group(1).strip()))
    return refs


def github_slug(heading: str) -> str:
    s = heading.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def check_section_refs(errors, refs):
    design = ROOT / "DESIGN.md"
    if not design.exists():
        errors.append("DESIGN.md does not exist but source files cite it")
        return
    headings = headings_of(design)

    def resolves(ref: str) -> bool:
        # tolerate prose flowing after the section name (whitespace was
        # collapsed): the reference resolves iff it IS a heading or
        # continues one at a word boundary
        return any(ref == h or ref.startswith(h + " ") for h in headings)

    for src, ref in refs:
        if not resolves(ref):
            errors.append(
                f"{src}: 'DESIGN.md §{ref}' does "
                f"not match any DESIGN.md heading {headings}")


def check_dead_sections(errors, refs):
    """Guarantee 3: every H2/H3 of DESIGN.md is cited from >= 1
    docstring (the reverse direction of check_section_refs)."""
    design = ROOT / "DESIGN.md"
    if not design.exists():
        return
    cited = [ref for _, ref in refs]
    for level, h in leveled_headings_of(design):
        if level not in (2, 3):
            continue
        if not any(ref == h or ref.startswith(h + " ") for ref in cited):
            errors.append(
                f"DESIGN.md: H{level} section '{h}' is cited by no source "
                f"file (dead section — cite it from the module that "
                f"implements it, or fold it into a live section)")


def check_markdown_links(errors):
    for doc in DOC_FILES:
        path = ROOT / doc
        if not path.exists():
            errors.append(f"{doc}: missing documentation file")
            continue
        fenced = False
        for ln, line in enumerate(path.read_text(encoding="utf-8")
                                  .splitlines(), 1):
            if line.lstrip().startswith("```"):
                fenced = not fenced
                continue
            if fenced:
                continue
            for m in MD_LINK.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                file_part, _, anchor = target.partition("#")
                dest = (path.parent / file_part).resolve() if file_part \
                    else path
                if not dest.exists():
                    errors.append(f"{doc}:{ln}: broken link '{target}'")
                    continue
                if anchor and dest.suffix == ".md":
                    slugs = [github_slug(h) for h in headings_of(dest)]
                    if anchor not in slugs:
                        errors.append(f"{doc}:{ln}: anchor '#{anchor}' not a "
                                      f"heading of {file_part or doc}")


def main() -> int:
    errors: list[str] = []
    refs = all_section_refs()
    check_section_refs(errors, refs)
    check_dead_sections(errors, refs)
    check_markdown_links(errors)
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        n_docs = len(DOC_FILES)
        print(f"check_docs: OK (section refs, dead-section scan + links "
              f"across {n_docs} docs)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
