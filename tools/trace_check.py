#!/usr/bin/env python3
"""Validate exported Chrome/Perfetto trace_event JSON (stdlib-only).

Checks, per trace file:

  1. well-formed JSON with a ``traceEvents`` list;
  2. every event has ``name``/``ph``/``ts``/``pid``/``tid`` with
     numeric timestamps, and ``X`` events carry a non-negative ``dur``;
  3. ``B``/``E`` duration events balance per (pid, tid) track with
     LIFO name matching;
  4. per-track timestamps are monotonically non-decreasing in file
     order (the exporter sorts by start time; a violation means a
     clock-domain mix-up — see DESIGN.md §Clock domains).

``--require-overlap A B`` additionally demands at least one pair of
concurrently-open ``X`` spans between a track whose thread name
contains A and one containing B — the gate the async-overlap benchmark
uses to prove rollout and trainer lanes actually overlap.

Run in the benchmark-smoke CI lane against the trace emitted by
``benchmarks/async_overlap.py``.

Usage:
    python tools/trace_check.py TRACE.json [...] [--require-overlap A B]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

VALID_PH = {"B", "E", "X", "i", "I", "C", "M", "b", "e", "n", "s", "t", "f"}


def load(path: str) -> Dict[str, Any]:
    with open(path, "r", encoding="utf-8") as f:
        return json.load(f)


def validate(trace: Dict[str, Any]) -> List[str]:
    """Return a list of human-readable errors (empty == valid)."""
    errors: List[str] = []
    events = trace.get("traceEvents")
    if not isinstance(events, list):
        return ["top-level 'traceEvents' missing or not a list"]
    if not events:
        errors.append("trace has no events")

    last_ts: Dict[Tuple[Any, Any], float] = {}
    open_spans: Dict[Tuple[Any, Any], List[str]] = {}

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event #{i}: not an object")
            continue
        ph = ev.get("ph")
        name = ev.get("name")
        if ph not in VALID_PH:
            errors.append(f"event #{i} ({name!r}): bad ph {ph!r}")
            continue
        if not isinstance(name, str) or not name:
            errors.append(f"event #{i}: missing name")
        if ph == "M":
            continue                       # metadata carries no ts
        ts = ev.get("ts")
        pid, tid = ev.get("pid"), ev.get("tid")
        if not isinstance(ts, (int, float)):
            errors.append(f"event #{i} ({name!r}): non-numeric ts {ts!r}")
            continue
        if pid is None or tid is None:
            errors.append(f"event #{i} ({name!r}): missing pid/tid")
            continue
        track = (pid, tid)
        prev = last_ts.get(track)
        if prev is not None and ts < prev:
            errors.append(
                f"event #{i} ({name!r}): ts {ts} < previous {prev} "
                f"on track pid={pid} tid={tid} (non-monotonic)")
        last_ts[track] = ts
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"event #{i} ({name!r}): X span with bad dur {dur!r}")
        elif ph == "B":
            open_spans.setdefault(track, []).append(name)
        elif ph == "E":
            stack = open_spans.get(track) or []
            if not stack:
                errors.append(
                    f"event #{i} ({name!r}): E without matching B on "
                    f"track pid={pid} tid={tid}")
            else:
                top = stack.pop()
                if name and top != name:
                    errors.append(
                        f"event #{i}: E {name!r} closes B {top!r} "
                        f"(interleaved, not nested)")
    for (pid, tid), stack in open_spans.items():
        if stack:
            errors.append(
                f"unbalanced spans on track pid={pid} tid={tid}: "
                f"{stack} never closed")
    return errors


def _track_names(trace: Dict[str, Any]) -> Dict[Tuple[Any, Any], str]:
    names: Dict[Tuple[Any, Any], str] = {}
    for ev in trace.get("traceEvents", []):
        if isinstance(ev, dict) and ev.get("ph") == "M" \
                and ev.get("name") == "thread_name":
            names[(ev.get("pid"), ev.get("tid"))] = \
                str(ev.get("args", {}).get("name", ""))
    return names


def concurrent_span_pairs(trace: Dict[str, Any], needle_a: str,
                          needle_b: str) -> int:
    """Count pairs of X spans — one on a track whose thread name
    contains ``needle_a``, one on a ``needle_b`` track — whose
    [ts, ts+dur) intervals overlap in time.  > 0 proves the two lanes
    genuinely ran concurrently."""
    names = _track_names(trace)

    def spans_on(needle: str) -> List[Tuple[float, float]]:
        out = []
        for ev in trace.get("traceEvents", []):
            if not (isinstance(ev, dict) and ev.get("ph") == "X"):
                continue
            track = (ev.get("pid"), ev.get("tid"))
            if needle.lower() in names.get(track, "").lower():
                ts = float(ev["ts"])
                out.append((ts, ts + float(ev.get("dur", 0))))
        return out

    a_spans, b_spans = spans_on(needle_a), spans_on(needle_b)
    pairs = 0
    for a0, a1 in a_spans:
        for b0, b1 in b_spans:
            if a0 < b1 and b0 < a1:
                pairs += 1
    return pairs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("traces", nargs="+", help="trace JSON file(s)")
    ap.add_argument("--require-overlap", nargs=2, metavar=("A", "B"),
                    help="fail unless an A-track span and a B-track "
                         "span overlap in time")
    args = ap.parse_args(argv)

    failed = False
    for path in args.traces:
        try:
            trace = load(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: unreadable or invalid JSON: {e}")
            failed = True
            continue
        errors = validate(trace)
        n_events = len(trace.get("traceEvents") or [])
        if errors:
            failed = True
            print(f"FAIL {path}: {len(errors)} error(s) in "
                  f"{n_events} events")
            for e in errors[:20]:
                print(f"  - {e}")
        else:
            print(f"OK   {path}: {n_events} events")
        if args.require_overlap:
            a, b = args.require_overlap
            pairs = concurrent_span_pairs(trace, a, b)
            if pairs > 0:
                print(f"     overlap {a!r}×{b!r}: "
                      f"{pairs} concurrent span pair(s)")
            else:
                failed = True
                print(f"FAIL {path}: no concurrent span pairs between "
                      f"{a!r} and {b!r} tracks")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
