"""Staleness x objective ablation at laptop scale (Table 2 / Fig. 5
shape): sweep eta with and without the decoupled PPO objective on the
synthetic math task and print the final accuracies.

    PYTHONPATH=src python examples/staleness_ablation.py --steps 15
"""
import argparse

import numpy as np

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--etas", type=int, nargs="+", default=[0, 1, 4])
    args = ap.parse_args()

    print(f"{'eta':>5s} {'objective':>10s} {'accuracy':>9s} "
          f"{'reward':>8s} {'virt_time':>10s}")
    for eta in args.etas:
        for decoupled in (True, False):
            if eta == 0 and not decoupled:
                continue
            ctl, trainer, reward = run_training(
                steps=args.steps, eta=eta, decoupled=decoupled,
                batch_size=32, answers_per_prompt=4, n_slots=16,
                log_every=10**9, seed=1)
            tail = ctl.history[-3:]
            print(f"{eta:>5d} {'decoupled' if decoupled else 'naive':>10s} "
                  f"{np.mean([h.accuracy for h in tail]):>9.3f} "
                  f"{np.mean([h.reward_mean for h in tail]):>+8.2f} "
                  f"{ctl.clock:>9.1f}s")


if __name__ == "__main__":
    main()
