"""Interruptible serving demo: batched requests stream through the
rollout engine while 'training' publishes new weights mid-flight — the
engine discards device state, re-prefills every prefix under the new
weights and continues decoding (paper Sec 4.1 + Fig. 3).

Also demonstrates the disaggregated two-submesh layout when >=2 local
devices exist (run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 to see it).

    PYTHONPATH=src python examples/serve_interruptible.py
    PYTHONPATH=src python examples/serve_interruptible.py --cache paged

``--cache paged`` swaps the per-slot ring buffers for the paged KV
block pool (DESIGN.md §Paged KV-cache pool): shared prompts map to
shared read-only blocks and the mid-flight weight update only rewrites
the blocks the version bump invalidated — watch ``prefix blocks
reused`` and the smaller re-prefill count in the output.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_model_config, reduced
from repro.core import EngineConfig, RolloutEngine
from repro.data import tokenizer
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cache", default="ring", choices=["ring", "paged"],
                    help="KV-cache organization: per-slot ring buffers "
                         "(default) or the paged block pool with prefix "
                         "sharing (block size 16 tokens by default)")
    ap.add_argument("--block-size", type=int, default=4,
                    help="tokens per KV block for --cache paged (engine "
                         "default is 16; the demo uses 4 so its short "
                         "prompts span full, shareable blocks)")
    args = ap.parse_args()

    cfg = reduced(get_model_config("h2o-danube-1.8b"))  # SWA ring caches
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=tokenizer.VOCAB_SIZE)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    engine = RolloutEngine(model, params, cfg=EngineConfig(
        n_slots=6, prompt_len=16, max_gen_len=12, seed=0, cache=args.cache,
        block_size=args.block_size))

    # GRPO-style groups: each prompt sampled twice, so in paged mode the
    # second sample of a group shares its prompt's full KV blocks
    prompts = [tokenizer.encode(f"<q> {a} + {b} = ?", bos=True)
               for a, b in [(1, 2), (3, 4), (5, 6)] for _ in range(2)]
    engine.admit([{"rid": i, "prompt_id": i // 2, "prompt": p, "answer": None}
                  for i, p in enumerate(prompts)])
    print(f"admitted {engine.n_active} requests "
          f"({engine.prefill_tokens} prompt tokens prefilled)")

    finished = []
    for step in range(30):
        finished += engine.step()
        if step == 3:       # a new policy version arrives mid-generation
            new_params = jax.tree.map(lambda x: x * 1.001, engine.params)
            engine.update_weights(new_params, version=1)
            print(f"step {step}: update_weights -> interrupted "
                  f"{engine.n_active} in-flight requests, re-prefilled "
                  f"{engine.reprefill_tokens} tokens under v1")
        if not engine.n_active and not finished:
            break
        if len(finished) == len(prompts):
            break

    for f in sorted(finished, key=lambda f: f.rid):
        versions = sorted(set(f.versions))
        print(f"req {f.rid}: {len(f.response):2d} tokens, "
              f"policy versions {versions}, "
              f"text={tokenizer.decode(f.response)!r}")
    mixed = sum(1 for f in finished if len(set(f.versions)) > 1)
    print(f"\n{mixed}/{len(finished)} trajectories span multiple policy "
          f"versions (Proposition 1 handles these in the decoupled loss)")
    if args.cache == "paged":
        print(f"paged pool: {engine.prefix_reused_blocks} prefix blocks "
              f"reused at admission, {engine.reprefill_tokens} tokens "
              f"rewritten by the interrupt (deduped across sharers)")

    if len(jax.devices()) >= 2:
        print("\n-- disaggregated submesh demo --")
        from repro.launch.disaggregated import demo
        demo(n_steps=2)


if __name__ == "__main__":
    main()
