"""Interruptible serving demo: batched requests stream through the
rollout engine while 'training' publishes new weights mid-flight — the
engine discards device state, re-prefills every prefix under the new
weights and continues decoding (paper Sec 4.1 + Fig. 3).

Also demonstrates the disaggregated two-submesh layout when >=2 local
devices exist (run with
XLA_FLAGS=--xla_force_host_platform_device_count=8 to see it).

    PYTHONPATH=src python examples/serve_interruptible.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_model_config, reduced
from repro.core import RolloutEngine
from repro.data import tokenizer
from repro.models.model import build_model


def main():
    cfg = reduced(get_model_config("h2o-danube-1.8b"))  # SWA ring caches
    import dataclasses
    cfg = dataclasses.replace(cfg, vocab_size=tokenizer.VOCAB_SIZE)
    model = build_model(cfg, remat=False)
    params = model.init(jax.random.key(0))
    engine = RolloutEngine(model, params, n_slots=6, prompt_len=16,
                           max_gen_len=12, seed=0)

    prompts = [tokenizer.encode(f"<q> {a} + {b} = ?", bos=True)
               for a, b in [(1, 2), (3, 4), (5, 6), (7, 8), (2, 9), (4, 4)]]
    engine.admit([{"rid": i, "prompt_id": i, "prompt": p, "answer": None}
                  for i, p in enumerate(prompts)])
    print(f"admitted {engine.n_active} requests "
          f"({engine.prefill_tokens} prompt tokens prefilled)")

    finished = []
    for step in range(30):
        finished += engine.step()
        if step == 3:       # a new policy version arrives mid-generation
            new_params = jax.tree.map(lambda x: x * 1.001, engine.params)
            engine.update_weights(new_params, version=1)
            print(f"step {step}: update_weights -> interrupted "
                  f"{engine.n_active} in-flight requests, re-prefilled "
                  f"{engine.reprefill_tokens} tokens under v1")
        if not engine.n_active and not finished:
            break
        if len(finished) == len(prompts):
            break

    for f in sorted(finished, key=lambda f: f.rid):
        versions = sorted(set(f.versions))
        print(f"req {f.rid}: {len(f.response):2d} tokens, "
              f"policy versions {versions}, "
              f"text={tokenizer.decode(f.response)!r}")
    mixed = sum(1 for f in finished if len(set(f.versions)) > 1)
    print(f"\n{mixed}/{len(finished)} trajectories span multiple policy "
          f"versions (Proposition 1 handles these in the decoupled loss)")

    if len(jax.devices()) >= 2:
        print("\n-- disaggregated submesh demo --")
        from repro.launch.disaggregated import demo
        demo(n_steps=2)


if __name__ == "__main__":
    main()
