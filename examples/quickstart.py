"""Quickstart: the full AReaL pipeline in ~2 minutes on CPU.

A tiny Qwen-shaped policy learns single-digit arithmetic with
asynchronous PPO: interruptible rollout workers stream generations, the
staleness controller (eta=4) admits work, the trainer runs decoupled-PPO
updates, and weight updates interrupt + re-prefill in-flight requests.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.launch.train import run_training


def main():
    ctl, trainer, reward = run_training(
        arch="areal-qwen-1.5b",       # reduced to laptop scale automatically
        steps=12, eta=4, batch_size=32, answers_per_prompt=4,
        n_slots=16, max_operand=9, lr=3e-4, seed=1)
    print(f"\nDone: {trainer.version} PPO steps, "
          f"virtual time {ctl.clock:.1f}s, "
          f"accuracy {reward.recent_accuracy:.1%}, "
          f"{ctl.engine.interruptions} weight-update interruptions, "
          f"staleness histogram {ctl.stal_stats.histogram()}")


if __name__ == "__main__":
    main()
