"""End-to-end training driver: train a small model for a few hundred
PPO steps on the synthetic verifiable-math task, with checkpointing and
a final sync-vs-async comparison.

    PYTHONPATH=src python examples/train_async_math.py --steps 200
    PYTHONPATH=src python examples/train_async_math.py --arch olmo-1b --eta 8
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/train_async_math.py --runtime threaded

Any assigned architecture id works (reduced to laptop scale); see
``repro.configs.ARCH_IDS``.  ``--runtime threaded`` swaps the
virtual-clock executor for the real threaded disaggregated runtime
(DESIGN.md §Async runtime): with >1 visible device generation and
training run concurrently on disjoint submeshes.
"""
import argparse
import json
import time

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="areal-qwen-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--eta", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=32)
    ap.add_argument("--naive-ppo", action="store_true")
    ap.add_argument("--runtime", default="virtual",
                    choices=["virtual", "threaded"])
    ap.add_argument("--ckpt-dir", default="runs/ckpt_math")
    ap.add_argument("--compare-sync", action="store_true",
                    help="also run the synchronous colocated baseline and "
                         "report the virtual-time speedup (Table 1 style)")
    args = ap.parse_args()

    t0 = time.time()
    ctl, trainer, reward = run_training(
        args.arch, steps=args.steps, eta=args.eta,
        decoupled=not args.naive_ppo, batch_size=args.batch_size,
        answers_per_prompt=4, n_slots=16, ckpt_dir=args.ckpt_dir,
        log_every=max(1, args.steps // 50), seed=1, runtime=args.runtime)
    result = {
        "arch": args.arch, "runtime": args.runtime, "steps": trainer.version,
        "wall_minutes": (time.time() - t0) / 60,
        "final_accuracy": reward.recent_accuracy,
        "effective_throughput_tok_s": ctl.effective_throughput(),
    }
    if args.runtime == "virtual":
        result["virtual_hours"] = ctl.clock / 3600
    else:
        result["run_wall_s"] = ctl.clock
        result["trainer_busy_fraction"] = ctl.trainer_busy_s / max(ctl.clock,
                                                                   1e-9)
    if args.compare_sync and args.runtime == "virtual":
        ctl_s, _, _ = run_training(
            args.arch, steps=min(args.steps, 20), eta=0, colocated_sync=True,
            batch_size=args.batch_size, answers_per_prompt=4, n_slots=16,
            log_every=10**9, seed=1)
        per_step_async = ctl.clock / trainer.version
        per_step_sync = ctl_s.clock / max(ctl_s.trainer.version, 1)
        result["sync_vs_async_speedup"] = per_step_sync / per_step_async
    elif args.compare_sync:
        # the baseline's clock is virtual pod-seconds; a threaded run's is
        # real wall-seconds — the ratio would be meaningless.  The real
        # wall-clock comparison lives in benchmarks/async_overlap.py.
        result["sync_vs_async_speedup"] = None
    print(json.dumps(result, indent=2))


if __name__ == "__main__":
    main()
